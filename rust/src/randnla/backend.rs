//! Sketching backends: who performs the randomization step.
//!
//! The paper's whole point is that the *same* RandNLA algorithm can take
//! its Gaussian sketch from different devices. [`Sketcher`] is that seam:
//!
//! - [`DigitalSketcher`] — host CPU, explicit G (the "numerical" arm);
//! - [`PjrtSketcher`]    — AOT-compiled XLA projection (the GPU-baseline
//!   arm, running the L1 Pallas kernel or the plain dot);
//! - `OpuSketcher` (in [`crate::randnla::sketch`]) — the simulated
//!   photonic co-processor (the "optical" arm).

use anyhow::Result;

use crate::linalg::{matmul, Mat};
use crate::rng::Xoshiro256;
use crate::runtime::PjrtHandle;

/// A fixed m x n Gaussian sketching operator.
pub trait Sketcher: Send + Sync {
    /// Output (sketch) dimension m.
    fn m(&self) -> usize;
    /// Input dimension n.
    fn n(&self) -> usize;
    /// Apply: (n x k) -> (m x k), approximately G @ a with G iid N(0, 1).
    fn project(&self, a: &Mat) -> Mat;
    /// Human-readable arm label for reports.
    fn label(&self) -> &'static str;
}

/// Host-CPU digital Gaussian sketch (materialised G, exact matmul).
pub struct DigitalSketcher {
    g: Mat,
}

impl DigitalSketcher {
    pub fn new(m: usize, n: usize, seed: u64) -> Self {
        let mut rng = Xoshiro256::new(seed);
        Self { g: Mat::gaussian(m, n, 1.0, &mut rng) }
    }

    /// The explicit operator (tests / cross-validation).
    pub fn matrix(&self) -> &Mat {
        &self.g
    }
}

impl Sketcher for DigitalSketcher {
    fn m(&self) -> usize {
        self.g.rows
    }

    fn n(&self) -> usize {
        self.g.cols
    }

    fn project(&self, a: &Mat) -> Mat {
        matmul(&self.g, a)
    }

    fn label(&self) -> &'static str {
        "digital"
    }
}

/// XLA/PJRT-executed digital sketch: G is generated host-side once, the
/// projection runs through the AOT artifact ladder (pad/crop adapted) on
/// the PJRT engine thread.
pub struct PjrtSketcher {
    g: Mat,
    handle: PjrtHandle,
    /// Artifact prefix: "proj_xla" (plain dot) or "proj_pallas" (L1 kernel).
    prefix: &'static str,
}

impl PjrtSketcher {
    pub fn new(
        m: usize,
        n: usize,
        seed: u64,
        handle: PjrtHandle,
        use_pallas: bool,
    ) -> Result<Self> {
        let mut rng = Xoshiro256::new(seed);
        let g = Mat::gaussian(m, n, 1.0, &mut rng);
        let prefix = if use_pallas { "proj_pallas" } else { "proj_xla" };
        // Fail fast if no bucket can serve this shape.
        let ok = handle
            .buckets(prefix)?
            .iter()
            .any(|&(bm, bn)| bm >= m && bn >= n);
        if !ok {
            anyhow::bail!("no {prefix} bucket >= {m}x{n}");
        }
        Ok(Self { g, handle, prefix })
    }

    pub fn matrix(&self) -> &Mat {
        &self.g
    }
}

impl Sketcher for PjrtSketcher {
    fn m(&self) -> usize {
        self.g.rows
    }

    fn n(&self) -> usize {
        self.g.cols
    }

    fn project(&self, a: &Mat) -> Mat {
        self.handle
            .project(self.prefix, self.g.clone(), a.clone())
            .expect("PJRT projection failed")
    }

    fn label(&self) -> &'static str {
        match self.prefix {
            "proj_pallas" => "pjrt-pallas",
            _ => "pjrt-xla",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::rel_frobenius_error;

    #[test]
    fn digital_project_is_exact_matmul() {
        let s = DigitalSketcher::new(8, 32, 1);
        let mut rng = Xoshiro256::new(2);
        let a = Mat::gaussian(32, 5, 1.0, &mut rng);
        let got = s.project(&a);
        let want = matmul(s.matrix(), &a);
        assert_eq!(got, want);
        assert_eq!(s.m(), 8);
        assert_eq!(s.n(), 32);
    }

    #[test]
    fn digital_deterministic_by_seed() {
        let a = DigitalSketcher::new(4, 8, 7);
        let b = DigitalSketcher::new(4, 8, 7);
        assert_eq!(a.matrix(), b.matrix());
        let c = DigitalSketcher::new(4, 8, 8);
        assert_ne!(a.matrix(), c.matrix());
    }

    #[test]
    fn jl_property_preserves_norms_in_expectation() {
        // E[||Gx||^2 / m] = ||x||^2.
        let n = 64;
        let m = 48;
        let mut rng = Xoshiro256::new(3);
        let x = Mat::gaussian(n, 1, 1.0, &mut rng);
        let x_norm2: f64 = x.data.iter().map(|v| v * v).sum();
        let mut acc = 0.0;
        let trials = 50;
        for t in 0..trials {
            let s = DigitalSketcher::new(m, n, 100 + t);
            let gx = s.project(&x);
            acc += gx.data.iter().map(|v| v * v).sum::<f64>() / m as f64;
        }
        let mean = acc / trials as f64;
        assert!(
            (mean - x_norm2).abs() / x_norm2 < 0.1,
            "JL violated: {mean} vs {x_norm2}"
        );
    }

    #[test]
    fn gtg_concentrates_to_identity() {
        // G^T G / m ~= I for m >> 1 (the estimator's core property).
        let s = DigitalSketcher::new(512, 16, 5);
        let gtg = crate::linalg::matmul_tn(s.matrix(), s.matrix()).scale(1.0 / 512.0);
        // Theory: E||G^T G/m - I||_F ~ sqrt(n(n+1)/m)/sqrt(n) ≈ 0.18 here.
        let err = rel_frobenius_error(&Mat::eye(16), &gtg);
        assert!(err < 0.3, "G^T G / m far from I: {err}");
    }
}
