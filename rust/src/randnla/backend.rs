//! Sketching backends: who performs the randomization step.
//!
//! The paper's whole point is that the *same* RandNLA algorithm can take
//! its Gaussian sketch from different devices. [`Sketcher`] is that seam:
//!
//! - [`DigitalSketcher`] — host CPU, explicit G (the "numerical" arm);
//! - [`CounterSketcher`] — host CPU, counter-based G: any block of the
//!   operator is addressable by (row, col) alone, which is what makes
//!   aperture sharding across a device pool exact (shards of one logical
//!   G agree bit-for-bit, whatever the pool size);
//! - [`PjrtSketcher`]    — AOT-compiled XLA projection (the GPU-baseline
//!   arm, running the L1 Pallas kernel or the plain dot);
//! - `OpuSketcher` (in [`crate::randnla::sketch`]) — the simulated
//!   photonic co-processor (the "optical" arm).
//!
//! Fallibility: [`Sketcher::try_project`] is the serving-path entry point
//! — a dead device returns `Err` and the coordinator reroutes. The
//! infallible [`Sketcher::project`] stays for the algorithm layer; the
//! PJRT arm satisfies it by degrading to an exact host multiply with its
//! own operator instead of panicking.

use std::ops::Range;
use std::sync::Arc;

use anyhow::Result;

use crate::linalg::{matmul, Mat};
use crate::rng::philox::{block_to_normals, Philox4x32};
use crate::rng::Xoshiro256;
use crate::runtime::PjrtHandle;

/// A fixed m x n Gaussian sketching operator.
pub trait Sketcher: Send + Sync {
    /// Output (sketch) dimension m.
    fn m(&self) -> usize;
    /// Input dimension n.
    fn n(&self) -> usize;
    /// Apply: (n x k) -> (m x k), approximately G @ a with G iid N(0, 1).
    /// Must not fail: backends with fallible transports degrade to an
    /// equivalent host computation.
    fn project(&self, a: &Mat) -> Mat;
    /// Fallible apply for the serving path: backends that can lose their
    /// device return `Err` here so the pool scheduler can reroute.
    fn try_project(&self, a: &Mat) -> Result<Mat> {
        Ok(self.project(a))
    }
    /// Human-readable arm label for reports.
    fn label(&self) -> &'static str;
}

/// Host-CPU digital Gaussian sketch (materialised G, exact matmul).
pub struct DigitalSketcher {
    g: Mat,
}

impl DigitalSketcher {
    pub fn new(m: usize, n: usize, seed: u64) -> Self {
        let mut rng = Xoshiro256::new(seed);
        Self { g: Mat::gaussian(m, n, 1.0, &mut rng) }
    }

    /// The explicit operator (tests / cross-validation).
    pub fn matrix(&self) -> &Mat {
        &self.g
    }
}

impl Sketcher for DigitalSketcher {
    fn m(&self) -> usize {
        self.g.rows
    }

    fn n(&self) -> usize {
        self.g.cols
    }

    fn project(&self, a: &Mat) -> Mat {
        matmul(&self.g, a)
    }

    fn label(&self) -> &'static str {
        "digital"
    }
}

/// Counter-based digital Gaussian operator: entry `G[i, j]` of the full
/// (m x n) operator is a pure function of `(seed, i, j)` via Philox
/// (Box-Muller over one 4-lane block per 4 columns). Because any
/// rectangular [`block`](Self::block) is addressable independently, the
/// shard planner can hand disjoint blocks of *one* logical operator to
/// different pool devices and the recombined sketch is exactly the
/// unsharded one — same property the OPU's transmission matrix gets from
/// the same RNG.
pub struct CounterSketcher {
    key: Philox4x32,
    m: usize,
    n: usize,
}

impl CounterSketcher {
    pub fn new(m: usize, n: usize, seed: u64) -> Self {
        Self { key: Philox4x32::new(seed), m, n }
    }

    /// Random access to operator entry (i, j).
    #[inline]
    pub fn entry(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.m && j < self.n);
        let z = block_to_normals(self.key.block_at(i as u64, (j / 4) as u64));
        z[j % 4]
    }

    /// Materialise the (rows x cols) block of the operator. Blocks of one
    /// seed tile together bit-exactly: `block(r, c)` equals the matching
    /// slice of `block(0..m, 0..n)`.
    pub fn block(&self, rows: Range<usize>, cols: Range<usize>) -> Mat {
        debug_assert!(rows.end <= self.m && cols.end <= self.n);
        let mut out = Mat::zeros(rows.len(), cols.len());
        for (bi, i) in rows.enumerate() {
            let row = out.row_mut(bi);
            let mut j = cols.start;
            while j < cols.end {
                let z = block_to_normals(self.key.block_at(i as u64, (j / 4) as u64));
                let lane0 = j % 4;
                let take = (4 - lane0).min(cols.end - j);
                for t in 0..take {
                    row[j - cols.start + t] = z[lane0 + t];
                }
                j += take;
            }
        }
        out
    }

    /// The full explicit operator (tests / small problems).
    pub fn matrix(&self) -> Mat {
        self.block(0..self.m, 0..self.n)
    }
}

impl Sketcher for CounterSketcher {
    fn m(&self) -> usize {
        self.m
    }

    fn n(&self) -> usize {
        self.n
    }

    /// Materialises the operator per call — fine for tests and one-shot
    /// use; the coordinator's executor caches blocks instead.
    fn project(&self, a: &Mat) -> Mat {
        matmul(&self.matrix(), a)
    }

    fn label(&self) -> &'static str {
        "counter"
    }
}

/// XLA/PJRT-executed digital sketch: G is generated host-side once and
/// shared behind an `Arc` (the engine thread borrows it per call — the
/// hot path no longer deep-copies the operator), the projection runs
/// through the AOT artifact ladder (pad/crop adapted) on the PJRT engine
/// thread.
#[derive(Clone)]
pub struct PjrtSketcher {
    g: Arc<Mat>,
    handle: PjrtHandle,
    /// Artifact prefix: "proj_xla" (plain dot) or "proj_pallas" (L1 kernel).
    prefix: &'static str,
}

impl PjrtSketcher {
    pub fn new(
        m: usize,
        n: usize,
        seed: u64,
        handle: PjrtHandle,
        use_pallas: bool,
    ) -> Result<Self> {
        let mut rng = Xoshiro256::new(seed);
        let g = Arc::new(Mat::gaussian(m, n, 1.0, &mut rng));
        Self::from_operator(g, handle, use_pallas)
    }

    /// Wrap an existing operator (e.g. a counter-generated shard block)
    /// without copying it.
    pub fn from_operator(g: Arc<Mat>, handle: PjrtHandle, use_pallas: bool) -> Result<Self> {
        let prefix = if use_pallas { "proj_pallas" } else { "proj_xla" };
        // Fail fast if no bucket can serve this shape.
        let ok = handle
            .buckets(prefix)?
            .iter()
            .any(|&(bm, bn)| bm >= g.rows && bn >= g.cols);
        if !ok {
            anyhow::bail!("no {prefix} bucket >= {}x{}", g.rows, g.cols);
        }
        Ok(Self { g, handle, prefix })
    }

    pub fn matrix(&self) -> &Mat {
        &self.g
    }
}

impl Sketcher for PjrtSketcher {
    fn m(&self) -> usize {
        self.g.rows
    }

    fn n(&self) -> usize {
        self.g.cols
    }

    /// Infallible path: if the engine is gone, fall back to the exact
    /// host multiply with the same operator (no panic, same estimator,
    /// f64 instead of the artifact's f32).
    fn project(&self, a: &Mat) -> Mat {
        self.try_project(a).unwrap_or_else(|_| matmul(&self.g, a))
    }

    fn try_project(&self, a: &Mat) -> Result<Mat> {
        self.handle.project(self.prefix, self.g.clone(), a.clone())
    }

    fn label(&self) -> &'static str {
        match self.prefix {
            "proj_pallas" => "pjrt-pallas",
            _ => "pjrt-xla",
        }
    }
}

impl PjrtSketcher {
    /// Serving-path apply: the batch already lives behind an `Arc`
    /// (see the batcher's shard executor), so the engine thread shares
    /// it instead of receiving a deep copy of the request payload.
    pub fn try_project_shared(&self, a: &Arc<Mat>) -> Result<Mat> {
        self.handle.project(self.prefix, self.g.clone(), a.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::rel_frobenius_error;

    #[test]
    fn digital_project_is_exact_matmul() {
        let s = DigitalSketcher::new(8, 32, 1);
        let mut rng = Xoshiro256::new(2);
        let a = Mat::gaussian(32, 5, 1.0, &mut rng);
        let got = s.project(&a);
        let want = matmul(s.matrix(), &a);
        assert_eq!(got, want);
        assert_eq!(s.m(), 8);
        assert_eq!(s.n(), 32);
    }

    #[test]
    fn digital_deterministic_by_seed() {
        let a = DigitalSketcher::new(4, 8, 7);
        let b = DigitalSketcher::new(4, 8, 7);
        assert_eq!(a.matrix(), b.matrix());
        let c = DigitalSketcher::new(4, 8, 8);
        assert_ne!(a.matrix(), c.matrix());
    }

    #[test]
    fn counter_blocks_tile_bit_exactly() {
        let s = CounterSketcher::new(16, 37, 99);
        let full = s.matrix();
        // Arbitrary interior block, including lanes not aligned to 4.
        let b = s.block(3..11, 5..23);
        for i in 0..8 {
            for j in 0..18 {
                assert_eq!(b.at(i, j), full.at(3 + i, 5 + j), "({i},{j})");
            }
        }
        // Entry accessor agrees with block materialisation.
        assert_eq!(s.entry(7, 19), full.at(7, 19));
    }

    #[test]
    fn counter_operator_is_standard_gaussian() {
        let s = CounterSketcher::new(64, 256, 5);
        let g = s.matrix();
        let len = g.data.len() as f64;
        let mean: f64 = g.data.iter().sum::<f64>() / len;
        let var: f64 = g.data.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / len;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn counter_project_matches_explicit() {
        let s = CounterSketcher::new(8, 24, 3);
        let mut rng = Xoshiro256::new(4);
        let a = Mat::gaussian(24, 5, 1.0, &mut rng);
        assert_eq!(s.project(&a), matmul(&s.matrix(), &a));
        assert!(s.try_project(&a).is_ok());
        assert_eq!(s.label(), "counter");
    }

    #[test]
    fn jl_property_preserves_norms_in_expectation() {
        // E[||Gx||^2 / m] = ||x||^2.
        let n = 64;
        let m = 48;
        let mut rng = Xoshiro256::new(3);
        let x = Mat::gaussian(n, 1, 1.0, &mut rng);
        let x_norm2: f64 = x.data.iter().map(|v| v * v).sum();
        let mut acc = 0.0;
        let trials = 50;
        for t in 0..trials {
            let s = DigitalSketcher::new(m, n, 100 + t);
            let gx = s.project(&x);
            acc += gx.data.iter().map(|v| v * v).sum::<f64>() / m as f64;
        }
        let mean = acc / trials as f64;
        assert!(
            (mean - x_norm2).abs() / x_norm2 < 0.1,
            "JL violated: {mean} vs {x_norm2}"
        );
    }

    #[test]
    fn gtg_concentrates_to_identity() {
        // G^T G / m ~= I for m >> 1 (the estimator's core property).
        let s = DigitalSketcher::new(512, 16, 5);
        let gtg = crate::linalg::matmul_tn(s.matrix(), s.matrix()).scale(1.0 / 512.0);
        // Theory: E||G^T G/m - I||_F ~ sqrt(n(n+1)/m)/sqrt(n) ≈ 0.18 here.
        let err = rel_frobenius_error(&Mat::eye(16), &gtg);
        assert!(err < 0.3, "G^T G / m far from I: {err}");
    }
}
