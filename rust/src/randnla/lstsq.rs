//! Sketch-and-solve least squares — the canonical RandNLA primitive the
//! paper's conclusion gestures at ("many directions ... in HPC").
//!
//! argmin_x ||A x - b|| is solved on the *sketched* system
//! (GA) x ~ (Gb): one pass of the randomization device over [A | b],
//! then an O(m n^2) QR on the compressed rows instead of O(N n^2) on all
//! N rows. With m = O(n / eps) rows the solution is a (1+eps)-approx in
//! residual norm (Sarlós 2006) — checked statistically in the tests.
//!
//! [`sketch_precond_lstsq`] upgrades the (1+eps)-approximation to a
//! *residual guarantee*: the same sketch is QR-factored and its R used
//! as a right preconditioner for LSQR on the **full** system
//! (Blendenpik / LSRN style, Avron et al. 2010). Because `S A = Q R`
//! with S a subspace embedding, `A R^-1` has condition number
//! `(1+eps)/(1-eps)` — LSQR then converges to the exact least-squares
//! solution in a handful of iterations, independent of `cond(A)`.

use crate::linalg::{
    lstsq, matvec, solve_upper_transposed, solve_upper_triangular, thin_qr, vec_norm2, Mat,
    ThinQr,
};
use crate::randnla::backend::Sketcher;

/// Solve min ||A x - b|| via one shared sketch of A and b.
/// A is (N x n) with N = sketcher.n() rows; returns x (n).
pub fn sketched_lstsq(sketcher: &dyn Sketcher, a: &Mat, b: &[f64]) -> Vec<f64> {
    assert_eq!(a.rows, sketcher.n(), "rows of A must match sketcher input dim");
    assert_eq!(a.rows, b.len(), "rhs length");
    assert!(
        sketcher.m() >= a.cols,
        "sketch dim {} < unknowns {} — system would be underdetermined",
        sketcher.m(),
        a.cols
    );
    // One fused projection of [A | b] guarantees the same G for both.
    let mut ab = Mat::zeros(a.rows, a.cols + 1);
    for i in 0..a.rows {
        ab.row_mut(i)[..a.cols].copy_from_slice(a.row(i));
        ab.row_mut(i)[a.cols] = b[i];
    }
    let s = sketcher.project(&ab);
    let sa = s.col_slice(0, a.cols);
    let sb: Vec<f64> = (0..s.rows).map(|i| s.at(i, a.cols)).collect();
    lstsq(&sa, &sb)
}

/// Exact baseline.
pub fn exact_lstsq(a: &Mat, b: &[f64]) -> Vec<f64> {
    lstsq(a, b)
}

/// LSQR iteration budget + stopping tolerance for the refined solver.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LsqrOpts {
    /// Stop when the relative residual (consistent systems) or the
    /// relative normal-equations residual (inconsistent systems) drops
    /// below this.
    pub tol: f64,
    pub max_iters: usize,
}

impl Default for LsqrOpts {
    fn default() -> Self {
        Self { tol: 1e-10, max_iters: 64 }
    }
}

/// Outcome of the sketch-and-precondition solve.
#[derive(Clone, Debug)]
pub struct PrecondLstsq {
    pub x: Vec<f64>,
    /// LSQR iterations spent (0 = the sketched warm start already met
    /// the tolerance).
    pub iters: usize,
    /// Measured `||A x - b|| / ||b||` on the full system — the residual
    /// guarantee, not an estimate.
    pub rel_residual: f64,
    /// Whether LSQR's stopping test fired before `max_iters`.
    pub converged: bool,
}

/// Sketch-and-precondition least squares: one device pass over `[A | b]`
/// yields the sketched system; its thin-QR factor R right-preconditions
/// LSQR on the full system, starting from the sketched solution.
pub fn sketch_precond_lstsq(
    sketcher: &dyn Sketcher,
    a: &Mat,
    b: &[f64],
    opts: LsqrOpts,
) -> PrecondLstsq {
    assert_eq!(a.rows, sketcher.n(), "rows of A must match sketcher input dim");
    assert_eq!(a.rows, b.len(), "rhs length");
    assert!(
        sketcher.m() >= a.cols,
        "sketch dim {} < unknowns {} — system would be underdetermined",
        sketcher.m(),
        a.cols
    );
    // One fused projection of [A | b] — the same single pass (and the
    // same operator for both sides) as `sketched_lstsq`.
    let mut ab = Mat::zeros(a.rows, a.cols + 1);
    for i in 0..a.rows {
        ab.row_mut(i)[..a.cols].copy_from_slice(a.row(i));
        ab.row_mut(i)[a.cols] = b[i];
    }
    let s = sketcher.project(&ab);
    let sa = s.col_slice(0, a.cols);
    let sb: Vec<f64> = (0..s.rows).map(|i| s.at(i, a.cols)).collect();
    precond_refine(a, b, &sa, &sb, opts)
}

/// The host-algebra half of sketch-and-precondition: given the already
/// sketched system `(SA, Sb)`, QR-factor it, warm-start from the
/// sketched solution and run right-preconditioned LSQR on the full
/// system. Shared by [`sketch_precond_lstsq`] and the coordinator's
/// `Lstsq { refine }` job (whose sketches arrive via the serving plane).
pub fn precond_refine(
    a: &Mat,
    b: &[f64],
    sa: &Mat,
    sb: &[f64],
    opts: LsqrOpts,
) -> PrecondLstsq {
    assert_eq!(a.cols, sa.cols, "sketched system has wrong unknown count");
    let ThinQr { q: sq, r } = thin_qr(sa);
    // Warm start: the sketch-and-solve solution x0 = R^-1 (Sq^T Sb).
    let y0: Vec<f64> = (0..sq.cols)
        .map(|j| (0..sq.rows).map(|i| sq.at(i, j) * sb[i]).sum())
        .collect();
    let x0 = solve_upper_triangular(&r, &y0);

    // LSQR (Paige & Saunders 1982) on min ||(A R^-1) y - r0|| with
    // r0 = b - A x0; then x = x0 + R^-1 y. The preconditioned operator
    // is applied as closures — R is never inverted explicitly.
    let at = a.transpose();
    let apply = |v: &[f64]| -> Vec<f64> { matvec(a, &solve_upper_triangular(&r, v)) };
    let apply_t = |u: &[f64]| -> Vec<f64> { solve_upper_transposed(&r, &matvec(&at, u)) };

    let ax0 = matvec(a, &x0);
    let mut u: Vec<f64> = b.iter().zip(&ax0).map(|(bi, axi)| bi - axi).collect();
    let bnorm = vec_norm2(b).max(f64::MIN_POSITIVE);
    let beta0 = vec_norm2(&u);
    let d = a.cols;
    let mut y = vec![0.0; d];
    let mut iters = 0usize;
    let mut converged = beta0 <= opts.tol * bnorm;
    if !converged && beta0 > 0.0 {
        scale(&mut u, 1.0 / beta0);
        let mut v = apply_t(&u);
        let mut alpha = vec_norm2(&v);
        if alpha > 0.0 {
            scale(&mut v, 1.0 / alpha);
            let mut w = v.clone();
            let mut phi_bar = beta0;
            let mut rho_bar = alpha;
            let mut bnorm2_est = 0.0f64; // running ||A R^-1||_F^2 estimate
            for _ in 0..opts.max_iters {
                iters += 1;
                // Bidiagonalization step.
                let av = apply(&v);
                for (ui, avi) in u.iter_mut().zip(&av) {
                    *ui = avi - alpha * *ui;
                }
                let beta = vec_norm2(&u);
                if beta > 0.0 {
                    scale(&mut u, 1.0 / beta);
                }
                let atu = apply_t(&u);
                for (vi, atui) in v.iter_mut().zip(&atu) {
                    *vi = atui - beta * *vi;
                }
                bnorm2_est += alpha * alpha + beta * beta;
                alpha = vec_norm2(&v);
                if alpha > 0.0 {
                    scale(&mut v, 1.0 / alpha);
                }
                // Givens rotation updating the QR of the bidiagonal.
                let rho = (rho_bar * rho_bar + beta * beta).sqrt();
                let c = rho_bar / rho;
                let sn = beta / rho;
                let theta = sn * alpha;
                rho_bar = -c * alpha;
                let phi = c * phi_bar;
                phi_bar *= sn;
                for i in 0..d {
                    y[i] += (phi / rho) * w[i];
                    w[i] = v[i] - (theta / rho) * w[i];
                }
                // Stopping: residual small (consistent) or normal-
                // equations residual small (inconsistent — the optimum
                // has a nonzero residual, but its gradient vanishes).
                let rnorm = phi_bar;
                let arnorm = phi_bar * alpha * c.abs();
                let grad_floor =
                    opts.tol * bnorm2_est.sqrt().max(1.0) * rnorm.max(f64::MIN_POSITIVE);
                if rnorm <= opts.tol * bnorm || arnorm <= grad_floor {
                    converged = true;
                    break;
                }
            }
        } else {
            // r0 is orthogonal to range(A): x0 is already optimal.
            converged = true;
        }
    }

    let correction = solve_upper_triangular(&r, &y);
    let x: Vec<f64> = x0.iter().zip(&correction).map(|(a0, ci)| a0 + ci).collect();
    let ax = matvec(a, &x);
    let resid: Vec<f64> = ax.iter().zip(b).map(|(p, q)| p - q).collect();
    PrecondLstsq { x, iters, rel_residual: vec_norm2(&resid) / bnorm, converged }
}

fn scale(v: &mut [f64], s: f64) {
    for x in v.iter_mut() {
        *x *= s;
    }
}

/// Residual norm ||A x - b|| (the quantity sketching approximates).
pub fn residual_norm(a: &Mat, x: &[f64], b: &[f64]) -> f64 {
    let ax = crate::linalg::matvec(a, x);
    ax.iter()
        .zip(b)
        .map(|(p, q)| (p - q) * (p - q))
        .sum::<f64>()
        .sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::randnla::backend::DigitalSketcher;
    use crate::rng::Xoshiro256;

    fn overdetermined(n_rows: usize, n_cols: usize, noise: f64, seed: u64) -> (Mat, Vec<f64>, Vec<f64>) {
        let mut rng = Xoshiro256::new(seed);
        let a = Mat::gaussian(n_rows, n_cols, 1.0, &mut rng);
        let x_true: Vec<f64> = (0..n_cols).map(|_| rng.next_normal()).collect();
        let mut b = crate::linalg::matvec(&a, &x_true);
        for v in b.iter_mut() {
            *v += noise * rng.next_normal();
        }
        (a, x_true, b)
    }

    #[test]
    fn noiseless_system_recovered_exactly_in_expectation() {
        let (a, x_true, b) = overdetermined(256, 8, 0.0, 1);
        let s = DigitalSketcher::new(64, 256, 2);
        let x = sketched_lstsq(&s, &a, &b);
        // Consistent system: any full-rank sketch solves it exactly.
        for (u, v) in x.iter().zip(&x_true) {
            assert!((u - v).abs() < 1e-8, "{u} vs {v}");
        }
    }

    #[test]
    fn residual_within_constant_of_optimal() {
        let (a, _x, b) = overdetermined(512, 10, 0.5, 3);
        let opt = residual_norm(&a, &exact_lstsq(&a, &b), &b);
        let mut worst: f64 = 0.0;
        for t in 0..5u64 {
            let s = DigitalSketcher::new(128, 512, 10 + t);
            let x = sketched_lstsq(&s, &a, &b);
            let r = residual_norm(&a, &x, &b);
            worst = worst.max(r / opt);
        }
        // (1 + eps) approximation; m/n = 12.8 => eps well under 0.5.
        assert!(worst < 1.5, "residual blowup {worst}");
    }

    #[test]
    fn more_sketch_rows_tighter_solution() {
        let (a, _x, b) = overdetermined(512, 12, 0.3, 5);
        let opt = exact_lstsq(&a, &b);
        let dist = |m: usize| {
            let mut acc = 0.0;
            for t in 0..6u64 {
                let s = DigitalSketcher::new(m, 512, 40 + t);
                let x = sketched_lstsq(&s, &a, &b);
                acc += x
                    .iter()
                    .zip(&opt)
                    .map(|(u, v)| (u - v) * (u - v))
                    .sum::<f64>()
                    .sqrt();
            }
            acc / 6.0
        };
        let coarse = dist(24);
        let fine = dist(192);
        assert!(fine < coarse, "{coarse} -> {fine}");
    }

    #[test]
    #[should_panic(expected = "underdetermined")]
    fn undersized_sketch_rejected() {
        let (a, _x, b) = overdetermined(64, 16, 0.0, 7);
        let s = DigitalSketcher::new(8, 64, 8);
        sketched_lstsq(&s, &a, &b);
    }

    #[test]
    fn precond_reaches_the_exact_least_squares_solution() {
        // Noisy (inconsistent) system: LSQR with the sketch
        // preconditioner must land on the true argmin, not a
        // (1+eps)-approximation of it.
        let (a, _x, b) = overdetermined(512, 10, 0.5, 21);
        let s = DigitalSketcher::new(64, 512, 22);
        let out = sketch_precond_lstsq(&s, &a, &b, LsqrOpts::default());
        assert!(out.converged, "did not converge in {} iters", out.iters);
        let opt = exact_lstsq(&a, &b);
        for (u, v) in out.x.iter().zip(&opt) {
            assert!((u - v).abs() < 1e-7, "{u} vs {v}");
        }
        // Residual guarantee: matches the optimum to refinement accuracy.
        let r_opt = residual_norm(&a, &opt, &b) / b.iter().map(|v| v * v).sum::<f64>().sqrt();
        assert!(out.rel_residual <= r_opt * (1.0 + 1e-8), "{} vs {r_opt}", out.rel_residual);
    }

    #[test]
    fn precond_beats_sketch_only_residual() {
        let (a, _x, b) = overdetermined(512, 12, 0.4, 31);
        let s = DigitalSketcher::new(48, 512, 32);
        let sketch_only = residual_norm(&a, &sketched_lstsq(&s, &a, &b), &b);
        let refined = sketch_precond_lstsq(&s, &a, &b, LsqrOpts::default());
        let refined_resid = residual_norm(&a, &refined.x, &b);
        assert!(
            refined_resid <= sketch_only,
            "refinement worsened the residual: {refined_resid} vs {sketch_only}"
        );
        let opt = residual_norm(&a, &exact_lstsq(&a, &b), &b);
        assert!(refined_resid <= opt * (1.0 + 1e-8), "{refined_resid} vs opt {opt}");
    }

    #[test]
    fn precond_converges_fast_on_ill_conditioned_systems() {
        // Scale the columns of A across 3 orders of magnitude: plain
        // LSQR (identity preconditioner) stalls, the sketch
        // preconditioner does not — the whole point of the method.
        let (mut a, _x, b) = overdetermined(256, 8, 0.2, 41);
        for j in 0..a.cols {
            let sc = 10f64.powf(-3.0 * j as f64 / 7.0);
            for i in 0..a.rows {
                *a.at_mut(i, j) *= sc;
            }
        }
        let opts = LsqrOpts { tol: 1e-10, max_iters: 48 };
        let s = DigitalSketcher::new(64, 256, 42);
        let sa = s.project(&a);
        let sb_mat = s.project(&Mat::from_fn(a.rows, 1, |i, _| b[i]));
        let sb: Vec<f64> = (0..sb_mat.rows).map(|i| sb_mat.at(i, 0)).collect();
        let refined = precond_refine(&a, &b, &sa, &sb, opts);
        // Identity "preconditioner" (plain LSQR): R = I, warm start from
        // the unsketched origin-ish solve of the identity system.
        let plain = precond_refine(&a, &b, &Mat::eye(a.cols), &vec![0.0; a.cols], opts);
        assert!(refined.converged, "preconditioned LSQR stalled ({} iters)", refined.iters);
        assert!(
            refined.iters * 2 <= plain.iters || !plain.converged,
            "preconditioning gained nothing: {} vs {} iters",
            refined.iters,
            plain.iters
        );
        let opt = residual_norm(&a, &exact_lstsq(&a, &b), &b);
        let got = residual_norm(&a, &refined.x, &b);
        assert!(got <= opt * (1.0 + 1e-6), "{got} vs {opt}");
    }

    #[test]
    fn consistent_system_converges_to_zero_residual() {
        let (a, x_true, b) = overdetermined(128, 6, 0.0, 51);
        let s = DigitalSketcher::new(32, 128, 52);
        let out = sketch_precond_lstsq(&s, &a, &b, LsqrOpts::default());
        assert!(out.converged);
        assert!(out.rel_residual < 1e-9, "residual {}", out.rel_residual);
        for (u, v) in out.x.iter().zip(&x_true) {
            assert!((u - v).abs() < 1e-8, "{u} vs {v}");
        }
    }
}
