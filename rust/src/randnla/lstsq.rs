//! Sketch-and-solve least squares — the canonical RandNLA primitive the
//! paper's conclusion gestures at ("many directions ... in HPC").
//!
//! argmin_x ||A x - b|| is solved on the *sketched* system
//! (GA) x ~ (Gb): one pass of the randomization device over [A | b],
//! then an O(m n^2) QR on the compressed rows instead of O(N n^2) on all
//! N rows. With m = O(n / eps) rows the solution is a (1+eps)-approx in
//! residual norm (Sarlós 2006) — checked statistically in the tests.

use crate::linalg::{lstsq, Mat};
use crate::randnla::backend::Sketcher;

/// Solve min ||A x - b|| via one shared sketch of A and b.
/// A is (N x n) with N = sketcher.n() rows; returns x (n).
pub fn sketched_lstsq(sketcher: &dyn Sketcher, a: &Mat, b: &[f64]) -> Vec<f64> {
    assert_eq!(a.rows, sketcher.n(), "rows of A must match sketcher input dim");
    assert_eq!(a.rows, b.len(), "rhs length");
    assert!(
        sketcher.m() >= a.cols,
        "sketch dim {} < unknowns {} — system would be underdetermined",
        sketcher.m(),
        a.cols
    );
    // One fused projection of [A | b] guarantees the same G for both.
    let mut ab = Mat::zeros(a.rows, a.cols + 1);
    for i in 0..a.rows {
        ab.row_mut(i)[..a.cols].copy_from_slice(a.row(i));
        ab.row_mut(i)[a.cols] = b[i];
    }
    let s = sketcher.project(&ab);
    let sa = s.col_slice(0, a.cols);
    let sb: Vec<f64> = (0..s.rows).map(|i| s.at(i, a.cols)).collect();
    lstsq(&sa, &sb)
}

/// Exact baseline.
pub fn exact_lstsq(a: &Mat, b: &[f64]) -> Vec<f64> {
    lstsq(a, b)
}

/// Residual norm ||A x - b|| (the quantity sketching approximates).
pub fn residual_norm(a: &Mat, x: &[f64], b: &[f64]) -> f64 {
    let ax = crate::linalg::matvec(a, x);
    ax.iter()
        .zip(b)
        .map(|(p, q)| (p - q) * (p - q))
        .sum::<f64>()
        .sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::randnla::backend::DigitalSketcher;
    use crate::rng::Xoshiro256;

    fn overdetermined(n_rows: usize, n_cols: usize, noise: f64, seed: u64) -> (Mat, Vec<f64>, Vec<f64>) {
        let mut rng = Xoshiro256::new(seed);
        let a = Mat::gaussian(n_rows, n_cols, 1.0, &mut rng);
        let x_true: Vec<f64> = (0..n_cols).map(|_| rng.next_normal()).collect();
        let mut b = crate::linalg::matvec(&a, &x_true);
        for v in b.iter_mut() {
            *v += noise * rng.next_normal();
        }
        (a, x_true, b)
    }

    #[test]
    fn noiseless_system_recovered_exactly_in_expectation() {
        let (a, x_true, b) = overdetermined(256, 8, 0.0, 1);
        let s = DigitalSketcher::new(64, 256, 2);
        let x = sketched_lstsq(&s, &a, &b);
        // Consistent system: any full-rank sketch solves it exactly.
        for (u, v) in x.iter().zip(&x_true) {
            assert!((u - v).abs() < 1e-8, "{u} vs {v}");
        }
    }

    #[test]
    fn residual_within_constant_of_optimal() {
        let (a, _x, b) = overdetermined(512, 10, 0.5, 3);
        let opt = residual_norm(&a, &exact_lstsq(&a, &b), &b);
        let mut worst: f64 = 0.0;
        for t in 0..5u64 {
            let s = DigitalSketcher::new(128, 512, 10 + t);
            let x = sketched_lstsq(&s, &a, &b);
            let r = residual_norm(&a, &x, &b);
            worst = worst.max(r / opt);
        }
        // (1 + eps) approximation; m/n = 12.8 => eps well under 0.5.
        assert!(worst < 1.5, "residual blowup {worst}");
    }

    #[test]
    fn more_sketch_rows_tighter_solution() {
        let (a, _x, b) = overdetermined(512, 12, 0.3, 5);
        let opt = exact_lstsq(&a, &b);
        let dist = |m: usize| {
            let mut acc = 0.0;
            for t in 0..6u64 {
                let s = DigitalSketcher::new(m, 512, 40 + t);
                let x = sketched_lstsq(&s, &a, &b);
                acc += x
                    .iter()
                    .zip(&opt)
                    .map(|(u, v)| (u - v) * (u - v))
                    .sum::<f64>()
                    .sqrt();
            }
            acc / 6.0
        };
        let coarse = dist(24);
        let fine = dist(192);
        assert!(fine < coarse, "{coarse} -> {fine}");
    }

    #[test]
    #[should_panic(expected = "underdetermined")]
    fn undersized_sketch_rejected() {
        let (a, _x, b) = overdetermined(64, 16, 0.0, 7);
        let s = DigitalSketcher::new(8, 64, 8);
        sketched_lstsq(&s, &a, &b);
    }
}
