//! Randomized triangle counting — paper §II-B, eqs. (5)-(6).
//!
//! `T = Tr(A^3) / 6 ~= Tr((G A G^T / m)^3) / 6`: one symmetric sketch of
//! the adjacency matrix, then an O(m^3) trace of the compressed cube
//! instead of the naive O(n^3).

use crate::graph::Graph;
use crate::linalg::{trace_cubed, Mat};
use crate::randnla::backend::Sketcher;
use crate::randnla::sketch::symmetric_sketch;

/// Estimate the triangle count of `g` with the given sketcher.
pub fn estimate_triangles(sketcher: &dyn Sketcher, g: &Graph) -> f64 {
    estimate_triangles_dense(sketcher, &g.adjacency())
}

/// Same, from an explicit (symmetric) adjacency matrix.
pub fn estimate_triangles_dense(sketcher: &dyn Sketcher, a: &Mat) -> f64 {
    let b = symmetric_sketch(sketcher, a); // (G A G^T)/m
    trace_cubed(&b) / 6.0
}

/// Exact count via the dense trace identity (O(n^3) baseline the paper
/// calls "naive") — cross-checks `Graph::exact_triangles`.
pub fn exact_triangles_dense(a: &Mat) -> f64 {
    trace_cubed(a) / 6.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::erdos_renyi;
    use crate::graph::karate::{karate_club, KARATE_TRIANGLES};
    use crate::randnla::backend::DigitalSketcher;

    #[test]
    fn dense_exact_matches_combinatorial() {
        let g = erdos_renyi(60, 0.15, 1);
        let dense = exact_triangles_dense(&g.adjacency());
        assert!((dense - g.exact_triangles() as f64).abs() < 1e-6);
    }

    #[test]
    fn karate_estimate_in_range() {
        let g = karate_club();
        // m close to n: the sketch is nearly lossless.
        let mut acc = 0.0;
        let trials = 40;
        for t in 0..trials {
            let s = DigitalSketcher::new(32, 34, 400 + t);
            acc += estimate_triangles(&s, &g);
        }
        let mean = acc / trials as f64;
        let rel = (mean - KARATE_TRIANGLES as f64).abs() / KARATE_TRIANGLES as f64;
        assert!(rel < 0.35, "mean {mean} vs {KARATE_TRIANGLES} (rel {rel})");
    }

    #[test]
    fn er_estimate_tracks_truth() {
        let g = erdos_renyi(128, 0.1, 7);
        let truth = g.exact_triangles() as f64;
        let mut acc = 0.0;
        let trials = 30;
        for t in 0..trials {
            let s = DigitalSketcher::new(96, 128, 800 + t);
            acc += estimate_triangles(&s, &g);
        }
        let mean = acc / trials as f64;
        let rel = (mean - truth).abs() / truth;
        assert!(rel < 0.4, "mean {mean} vs {truth} (rel {rel})");
    }

    #[test]
    fn compression_sharpens_estimate() {
        let g = erdos_renyi(96, 0.15, 9);
        let truth = g.exact_triangles() as f64;
        let spread = |m: usize| {
            let trials = 25;
            let mut sq = 0.0;
            for t in 0..trials {
                let s = DigitalSketcher::new(m, 96, 60 + t);
                let e = estimate_triangles(&s, &g) - truth;
                sq += e * e;
            }
            (sq / trials as f64).sqrt() / truth
        };
        let coarse = spread(24);
        let fine = spread(80);
        assert!(fine < coarse, "{coarse} -> {fine}");
    }

    #[test]
    fn triangle_free_graph_estimates_near_zero() {
        // Star graph: no triangles; estimator should hover near 0
        // relative to the scale of a same-size triangle-rich graph.
        let mut g = Graph::new(40);
        for v in 1..40 {
            g.add_edge(0, v);
        }
        assert_eq!(g.exact_triangles(), 0);
        let mut acc = 0.0;
        let trials = 30;
        for t in 0..trials {
            let s = DigitalSketcher::new(32, 40, 70 + t);
            acc += estimate_triangles(&s, &g);
        }
        let mean = (acc / trials as f64).abs();
        assert!(mean < 30.0, "triangle-free mean {mean}");
    }
}
