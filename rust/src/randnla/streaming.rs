//! Streaming / one-pass RandNLA summaries: bounded-memory sketches of a
//! matrix whose rows arrive in chunks and are never all resident.
//!
//! This is the algorithm half of the streaming ingestion plane (the
//! protocol half — `StreamId` handles, chunk buffers, quota accounting —
//! lives in `coordinator/stream.rs`). Two summaries cover the one-pass
//! workload class:
//!
//! - [`ChunkSketch`] — the chunkwise left sketch `S·A`, accumulated one
//!   block of rows at a time. The operator is addressed by *absolute row
//!   offset* through [`RowBlockSketcher`], so the counter-seeded
//!   signature operators the resident serving plane uses (dense
//!   counter, SRHT, sparse-sign — the digital arms) serve streams: a
//!   fixed chunk schedule is bit-reproducible, and changing the chunk
//!   size only re-associates the f64 summation. (The OPU arm pins its
//!   media per cell shape and cannot address offsets coherently — the
//!   serving plane routes chunk batches to the digital arms, see
//!   `Router::schedule_chunk`.)
//! - [`FrequentDirections`] — Liberty's deterministic rank-ℓ row-space
//!   maintainer (SVD shrinkage per flush). The classic guarantee
//!   `‖AᵀA − BᵀB‖₂ ≤ ‖A‖²_F/(ℓ−k)` is surfaced as a *measured* bound:
//!   the cumulative shrinkage Σδ ([`FrequentDirections::bound`]) always
//!   dominates the true spectral error and is itself dominated by the
//!   theoretical bound ([`FrequentDirections::guarantee`]).
//!
//! On top of the summaries, [`solve_corange`] turns the pair
//! (range sketch `Y = A·Ω`, co-range sketch `S·A`) into the small matrix
//! `X ≈ QᵀA` that a single-pass randomized SVD factorises — no second
//! pass over A (Halko–Martinsson–Tropp §5.5 / Tropp et al. 2017). See
//! `docs/algorithms.md` ("Streaming one-pass estimators") for the
//! accuracy/cost discussion.

use std::ops::Range;

use crate::linalg::{self, matmul, thin_qr, Mat};
use crate::randnla::backend::CounterSketcher;
use crate::randnla::structured::{SparseSignSketcher, SrhtSketcher};

/// An operator whose column blocks are addressable by absolute input-row
/// offset: `project_rows(r0..r1, x)` computes `S[:, r0..r1] · x` for a
/// chunk `x` holding exactly rows `r0..r1` of the streamed matrix.
///
/// Every counter-seeded digital operator in the repo satisfies this (the
/// same property that makes aperture sharding exact); the OPU arm gets
/// it through the serving plane's shard executor instead.
pub trait RowBlockSketcher {
    /// Output (sketch) dimension m.
    fn m(&self) -> usize;
    /// Input dimension n (the stream's declared total rows).
    fn n(&self) -> usize;
    /// `S[:, inp] · x` with `x.rows == inp.len()`.
    fn project_rows(&self, inp: Range<usize>, x: &Mat) -> Mat;
}

impl RowBlockSketcher for CounterSketcher {
    fn m(&self) -> usize {
        crate::randnla::backend::Sketcher::m(self)
    }

    fn n(&self) -> usize {
        crate::randnla::backend::Sketcher::n(self)
    }

    fn project_rows(&self, inp: Range<usize>, x: &Mat) -> Mat {
        matmul(&self.block(0..RowBlockSketcher::m(self), inp), x)
    }
}

impl RowBlockSketcher for SrhtSketcher {
    fn m(&self) -> usize {
        crate::randnla::backend::Sketcher::m(self)
    }

    fn n(&self) -> usize {
        crate::randnla::backend::Sketcher::n(self)
    }

    fn project_rows(&self, inp: Range<usize>, x: &Mat) -> Mat {
        self.project_block(0..RowBlockSketcher::m(self), inp, x)
    }
}

impl RowBlockSketcher for SparseSignSketcher {
    fn m(&self) -> usize {
        crate::randnla::backend::Sketcher::m(self)
    }

    fn n(&self) -> usize {
        crate::randnla::backend::Sketcher::n(self)
    }

    fn project_rows(&self, inp: Range<usize>, x: &Mat) -> Mat {
        self.project_block(0..RowBlockSketcher::m(self), inp, x)
    }
}

/// One-pass accumulator of the left sketch `S·A`: absorb row chunks in
/// arrival order, each applied through a block of the one logical
/// operator at its absolute offset, and read the finished `m × cols`
/// sketch after the last row. Chunk-size changes only re-associate the
/// per-entry f64 sums; the operator entries themselves never move.
pub struct ChunkSketch {
    acc: Mat,
    n: usize,
    next_row: usize,
}

impl ChunkSketch {
    /// Accumulator for an `m × n`-operator sketch of an `n × cols` stream.
    pub fn new(m: usize, n: usize, cols: usize) -> Self {
        assert!(m > 0 && n > 0 && cols > 0, "chunk sketch needs positive dims");
        Self { acc: Mat::zeros(m, cols), n, next_row: 0 }
    }

    /// Rows absorbed so far (the absolute offset of the next chunk).
    pub fn rows_seen(&self) -> usize {
        self.next_row
    }

    /// Every declared row has been absorbed.
    pub fn done(&self) -> bool {
        self.next_row == self.n
    }

    /// Absorb the next chunk of rows through `sk` and return the absolute
    /// row range it covered.
    pub fn absorb(&mut self, sk: &impl RowBlockSketcher, chunk: &Mat) -> Range<usize> {
        assert_eq!(sk.m(), self.acc.rows, "operator m != accumulator m");
        assert_eq!(sk.n(), self.n, "operator n != declared stream rows");
        assert_eq!(chunk.cols, self.acc.cols, "chunk cols != stream cols");
        let r0 = self.next_row;
        let r1 = r0 + chunk.rows;
        assert!(r1 <= self.n, "chunk overruns the declared {} rows", self.n);
        self.add_partial(&sk.project_rows(r0..r1, chunk));
        self.next_row = r1;
        r0..r1
    }

    /// Accumulate an already-computed partial `S[:, r0..r1] · chunk` (the
    /// serving plane computes partials through the batcher and feeds them
    /// here; in-process callers use [`absorb`](Self::absorb)).
    pub fn absorb_partial(&mut self, partial: &Mat, rows: usize) -> Range<usize> {
        assert_eq!(
            (partial.rows, partial.cols),
            (self.acc.rows, self.acc.cols),
            "partial shape mismatch"
        );
        let r0 = self.next_row;
        let r1 = r0 + rows;
        assert!(r1 <= self.n, "chunk overruns the declared {} rows", self.n);
        self.add_partial(partial);
        self.next_row = r1;
        r0..r1
    }

    fn add_partial(&mut self, partial: &Mat) {
        for (acc, v) in self.acc.data.iter_mut().zip(&partial.data) {
            *acc += v;
        }
    }

    /// The accumulated sketch (valid once [`done`](Self::done)).
    pub fn sketch(&self) -> &Mat {
        &self.acc
    }

    /// Consume into the finished sketch. Panics if rows are missing.
    pub fn finish(self) -> Mat {
        assert!(self.done(), "stream short: {}/{} rows absorbed", self.next_row, self.n);
        self.acc
    }
}

/// Frequent Directions (Liberty 2013 / Ghashami et al. 2016): a
/// deterministic rank-ℓ sketch `B` of a row stream with
/// `‖AᵀA − BᵀB‖₂ ≤ Σδ ≤ ‖A‖²_F/(ℓ−k)` for every `k < ℓ`, where δ is the
/// squared singular value shrunk away at each flush. The buffer holds at
/// most 2ℓ rows; a flush SVDs it and keeps the top ℓ directions shrunk
/// by δ — bounded memory whatever the stream length.
pub struct FrequentDirections {
    ell: usize,
    cols: usize,
    /// Row buffer (≤ 2ℓ rows used); its used rows *are* the sketch B.
    buf: Mat,
    used: usize,
    /// Σδ — the measured bound on `‖AᵀA − BᵀB‖₂`.
    shrinkage: f64,
    /// Accumulated `‖A‖²_F` (exact; each inserted row counted once).
    fro2: f64,
    flushes: u64,
}

impl FrequentDirections {
    pub fn new(ell: usize, cols: usize) -> Self {
        assert!(ell >= 1 && cols >= 1, "FD needs positive dims, got ℓ={ell} cols={cols}");
        Self {
            ell,
            cols,
            buf: Mat::zeros(2 * ell, cols),
            used: 0,
            shrinkage: 0.0,
            fro2: 0.0,
            flushes: 0,
        }
    }

    /// Sketch rows ℓ.
    pub fn ell(&self) -> usize {
        self.ell
    }

    /// Rows currently in the sketch (≤ 2ℓ; ≤ ℓ after
    /// [`compress`](Self::compress)).
    pub fn rank(&self) -> usize {
        self.used
    }

    /// SVD-shrinkage flushes performed so far.
    pub fn flushes(&self) -> u64 {
        self.flushes
    }

    /// Measured bound: `‖AᵀA − BᵀB‖₂ ≤ Σδ` (each flush adds at most δ of
    /// spectral error to the Gram, exactly the δ it shrank by).
    pub fn bound(&self) -> f64 {
        self.shrinkage
    }

    /// Accumulated `‖A‖²_F` of everything inserted.
    pub fn fro2(&self) -> f64 {
        self.fro2
    }

    /// The classic a-priori guarantee `‖A‖²_F/(ℓ−k)`; the measured
    /// [`bound`](Self::bound) always sits at or below it.
    pub fn guarantee(&self, k: usize) -> f64 {
        assert!(k < self.ell, "guarantee needs k < ℓ ({k} >= {})", self.ell);
        self.fro2 / (self.ell - k) as f64
    }

    /// Insert a chunk of rows, flushing (SVD shrinkage) whenever the
    /// buffer fills.
    pub fn insert(&mut self, rows: &Mat) {
        assert_eq!(rows.cols, self.cols, "FD row width {} != {}", rows.cols, self.cols);
        self.fro2 += rows.data.iter().map(|v| v * v).sum::<f64>();
        self.insert_rows(rows);
    }

    /// Merge another FD summary into this one: stack the part's sketch
    /// rows (they enter the same flush machinery as data rows) and carry
    /// its error accounting across. The classic mergeability result
    /// (Ghashami et al. 2016, Thm. 1.2) is exactly this operation: for
    /// parts `B_1..B_p` of a row-partitioned `A`, the merged sketch
    /// satisfies `‖AᵀA − BᵀB‖₂ ≤ Σᵢ δᵢ + δ_merge`, the *composed* bound,
    /// which still sits under `‖A‖²_F/(ℓ−k)`.
    ///
    /// `bound` and `fro2` are the part's measured Σδ and exact `‖A_i‖²_F`
    /// — they must come from the part's own accounting, because the
    /// sketch rows alone under-count Frobenius mass already shrunk away.
    pub fn merge(&mut self, sketch: &Mat, bound: f64, fro2: f64) {
        assert_eq!(sketch.cols, self.cols, "FD merge width {} != {}", sketch.cols, self.cols);
        self.shrinkage += bound;
        self.fro2 += fro2;
        self.insert_rows(sketch);
    }

    /// The row-buffer/flush loop shared by [`insert`](Self::insert)
    /// (data rows, Frobenius-counted) and [`merge`](Self::merge)
    /// (sketch rows, accounting carried by the caller).
    fn insert_rows(&mut self, rows: &Mat) {
        let mut at = 0usize;
        while at < rows.rows {
            let take = (2 * self.ell - self.used).min(rows.rows - at);
            for i in 0..take {
                self.buf.row_mut(self.used + i).copy_from_slice(rows.row(at + i));
            }
            self.used += take;
            at += take;
            if self.used == 2 * self.ell {
                self.flush();
            }
        }
    }

    /// Ensure the sketch holds at most ℓ rows (one extra flush if the
    /// buffer sits in its slack half) — the sealed, bounded form.
    pub fn compress(&mut self) {
        if self.used > self.ell {
            self.flush();
        }
    }

    /// Copy of the current sketch B (rank() × cols).
    pub fn sketch(&self) -> Mat {
        Mat::from_fn(self.used, self.cols, |i, j| self.buf.at(i, j))
    }

    /// SVD shrinkage: keep the top ℓ directions, each shrunk by
    /// δ = σ²_{ℓ+1} in the squared spectrum; discard the rest. Removes at
    /// least ℓ·δ of Frobenius mass, which is what caps Σδ at
    /// `‖A‖²_F/(ℓ−k)`.
    fn flush(&mut self) {
        if self.used <= self.ell {
            return;
        }
        let b = self.sketch();
        let linalg::Svd { s, vt, .. } = linalg::svd(&b);
        self.flushes += 1;
        if s.len() <= self.ell {
            // Fewer directions than ℓ: rewrite exactly, no shrinkage.
            for (i, &sv) in s.iter().enumerate() {
                let row = self.buf.row_mut(i);
                for (j, dst) in row.iter_mut().enumerate() {
                    *dst = sv * vt.at(i, j);
                }
            }
            self.used = s.len();
            return;
        }
        let delta = s[self.ell] * s[self.ell];
        self.shrinkage += delta;
        for i in 0..self.ell {
            let sv = (s[i] * s[i] - delta).max(0.0).sqrt();
            let row = self.buf.row_mut(i);
            for (j, dst) in row.iter_mut().enumerate() {
                *dst = sv * vt.at(i, j);
            }
        }
        self.used = self.ell;
    }
}

/// Canonical left-fold of per-partition `S·A` accumulators covering
/// disjoint row ranges of one stream: `((p₀ + p₁) + p₂) + …` in the
/// caller-supplied order. The cluster plane always passes partials in
/// ascending row-offset order, which makes the merged accumulator a
/// *fixed* f64 association — independent of how many workers produced
/// the partials and of the reduction tree's arity. (Summing in tree
/// order instead would re-associate the sums and move last bits.)
pub fn fold_partials(parts: &[Mat]) -> Mat {
    assert!(!parts.is_empty(), "fold_partials needs at least one partial");
    let (m, cols) = (parts[0].rows, parts[0].cols);
    let mut acc = Mat::zeros(m, cols);
    for p in parts {
        assert_eq!((p.rows, p.cols), (m, cols), "partial shape mismatch");
        for (a, v) in acc.data.iter_mut().zip(&p.data) {
            *a += v;
        }
    }
    acc
}

/// The one-pass co-range solve: `X = argmin_X ‖(SQ)·X − (S·A)‖_F`,
/// column by column through one thin QR of `SQ` — the single-pass
/// substitute for `B = QᵀA` (which would need a second pass over A).
/// Requires `sq.rows >= sq.cols` (the stream's sketch width must cover
/// the range basis).
pub fn solve_corange(sq: &Mat, sa: &Mat) -> Mat {
    assert!(
        sq.rows >= sq.cols,
        "co-range solve underdetermined: sketch width {} < basis {}",
        sq.rows,
        sq.cols
    );
    assert_eq!(sq.rows, sa.rows, "SQ rows {} != SA rows {}", sq.rows, sa.rows);
    let qr = thin_qr(sq);
    // Qᵀ(SA), then back-substitute R X = Qᵀ(SA) one column at a time.
    let qtsa = linalg::matmul_tn(&qr.q, sa);
    let mut x = Mat::zeros(sq.cols, sa.cols);
    for j in 0..sa.cols {
        let col: Vec<f64> = (0..qtsa.rows).map(|i| qtsa.at(i, j)).collect();
        let sol = linalg::solve_upper_triangular(&qr.r, &col);
        for (i, v) in sol.into_iter().enumerate() {
            *x.at_mut(i, j) = v;
        }
    }
    x
}

/// What an in-process one-pass randomized SVD yields.
pub struct OnePassSvd {
    pub u: Mat,
    pub s: Vec<f64>,
    pub vt: Mat,
    /// Measured FD bound Σδ on `‖AᵀA − BᵀB‖₂` for the stream.
    pub fd_bound: f64,
    /// Accumulated `‖A‖²_F`.
    pub fro2: f64,
}

/// In-process single-pass randomized SVD over a chunked row stream,
/// with both operators drawn from counter sketchers (the host arm's
/// dense signature family): the range sketch `Y = A·Ω` accumulates one
/// chunk of rows at a time, the co-range `S·A` through [`ChunkSketch`],
/// and a rank-ℓ [`FrequentDirections`] rides along to certify the
/// stream. A is only ever touched chunk by chunk — the convenience
/// driver for tests and benches; the serving plane's
/// `JobSpec::RandSvd { a: OperandRef::Stream(..) }` is the production
/// path (see `coordinator/stream.rs`).
#[allow(clippy::too_many_arguments)]
pub fn one_pass_randsvd_digital(
    a: &Mat,
    chunk_rows: usize,
    rank: usize,
    oversample: usize,
    sketch_m: usize,
    fd_rank: usize,
    seed: u64,
) -> OnePassSvd {
    let cap = rank + oversample;
    assert!(cap >= 1 && sketch_m >= cap, "need sketch_m >= rank+oversample");
    let (rows, cols) = (a.rows, a.cols);
    // Range operator Ω' (cap × cols) and left operator S (sketch_m × rows),
    // both counter-seeded like the serving plane's signature operators.
    let omega = CounterSketcher::new(cap, cols, seed);
    let s_op = CounterSketcher::new(sketch_m, rows, seed ^ 0x5357_4541_4D5F_5341);
    let mut yt = Mat::zeros(cap, rows);
    let mut sa = ChunkSketch::new(sketch_m, rows, cols);
    let mut fd = FrequentDirections::new(fd_rank, cols);
    let mut r0 = 0usize;
    while r0 < rows {
        let r1 = (r0 + chunk_rows.max(1)).min(rows);
        let chunk = Mat::from_fn(r1 - r0, cols, |i, j| a.at(r0 + i, j));
        // Y[r0..r1, :] = chunk · Ω, computed as Ω'·chunkᵀ — the same
        // orientation the serving plane projects.
        let y_block = crate::randnla::backend::Sketcher::project(&omega, &chunk.transpose());
        for i in 0..cap {
            yt.row_mut(i)[r0..r1].copy_from_slice(y_block.row(i));
        }
        sa.absorb(&s_op, &chunk);
        fd.insert(&chunk);
        r0 = r1;
    }
    fd.compress();
    let q = linalg::orthonormalize(&yt.transpose());
    let sq = crate::randnla::backend::Sketcher::project(&s_op, &q);
    let x = solve_corange(&sq, sa.sketch());
    let linalg::Svd { u: ux, s, vt } = linalg::svd(&x);
    let u = matmul(&q, &ux);
    let k = rank.min(s.len());
    OnePassSvd {
        u: u.crop(u.rows, k),
        s: s[..k].to_vec(),
        vt: vt.crop(k, vt.cols),
        fd_bound: fd.bound(),
        fro2: fd.fro2(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{matmul_tn, rel_frobenius_error, spectral_norm};
    use crate::randnla::backend::Sketcher;
    use crate::rng::Xoshiro256;
    use crate::workload::{matrix_with_spectrum, Spectrum};

    /// Chunk `a` through the accumulator and compare against the plain
    /// operator apply.
    fn assert_chunked_matches(sk: &(impl RowBlockSketcher + Sketcher), a: &Mat, chunk: usize) {
        let full = Sketcher::project(sk, a);
        let mut acc = ChunkSketch::new(RowBlockSketcher::m(sk), a.rows, a.cols);
        let mut r0 = 0usize;
        while r0 < a.rows {
            let r1 = (r0 + chunk).min(a.rows);
            let x = Mat::from_fn(r1 - r0, a.cols, |i, j| a.at(r0 + i, j));
            acc.absorb(sk, &x);
            r0 = r1;
        }
        assert!(acc.done());
        let rel = rel_frobenius_error(&full, acc.sketch());
        assert!(rel < 1e-12, "{} chunk={chunk} drifted {rel}", Sketcher::label(sk));
    }

    #[test]
    fn chunk_sketch_matches_whole_matrix_apply_for_every_arm() {
        let (m, n, cols) = (12usize, 40usize, 6usize);
        let mut rng = Xoshiro256::new(1);
        let a = Mat::gaussian(n, cols, 1.0, &mut rng);
        for chunk in [1usize, 7, 16, 40] {
            assert_chunked_matches(&CounterSketcher::new(m, n, 9), &a, chunk);
            assert_chunked_matches(&SrhtSketcher::new(m, n, 9), &a, chunk);
            assert_chunked_matches(&SparseSignSketcher::new(m, n, 4, 9), &a, chunk);
        }
    }

    #[test]
    fn chunk_schedule_is_deterministic_and_unchunked_is_bitwise() {
        // The same chunk schedule gives bit-identical accumulators; a
        // single full-width chunk equals the plain operator apply bit
        // for bit (no re-association at all).
        let (m, n, cols) = (8usize, 24usize, 3usize);
        let mut rng = Xoshiro256::new(2);
        let a = Mat::gaussian(n, cols, 1.0, &mut rng);
        let op = CounterSketcher::new(m, n, 5);
        let run = |chunk: usize| {
            let mut acc = ChunkSketch::new(m, n, cols);
            let mut r0 = 0usize;
            while r0 < n {
                let r1 = (r0 + chunk).min(n);
                let x = Mat::from_fn(r1 - r0, cols, |i, j| a.at(r0 + i, j));
                acc.absorb(&op, &x);
                r0 = r1;
            }
            acc.finish()
        };
        assert_eq!(run(5), run(5), "fixed schedule must be bit-stable");
        assert_eq!(run(n), Sketcher::project(&op, &a), "one chunk = plain apply");
    }

    #[test]
    fn fd_bound_dominates_true_gram_error_across_seeds_and_chunks() {
        // Property: measured Σδ ≥ ‖AᵀA − BᵀB‖₂ ≥ 0, and Σδ stays under
        // the classic ‖A‖²_F/(ℓ−k) guarantee — across seeds and chunk
        // schedules.
        let (n, cols, ell) = (48usize, 32usize, 12usize);
        for seed in [3u64, 11, 29] {
            let a = matrix_with_spectrum(n, Spectrum::Exponential { decay: 0.85 }, seed)
                .crop(n, cols);
            for chunk in [5usize, 16, 48] {
                let mut fd = FrequentDirections::new(ell, cols);
                let mut r0 = 0usize;
                while r0 < n {
                    let r1 = (r0 + chunk).min(n);
                    fd.insert(&Mat::from_fn(r1 - r0, cols, |i, j| a.at(r0 + i, j)));
                    r0 = r1;
                }
                fd.compress();
                assert!(fd.rank() <= ell, "sealed FD must hold <= ℓ rows");
                let b = fd.sketch();
                let diff = matmul_tn(&a, &a).sub(&matmul_tn(&b, &b));
                let direct = spectral_norm(&diff, 200, 7);
                let fro2: f64 = a.data.iter().map(|v| v * v).sum();
                assert!((fd.fro2() - fro2).abs() < 1e-9 * fro2);
                assert!(
                    direct <= fd.bound() * (1.0 + 1e-9) + 1e-12,
                    "seed {seed} chunk {chunk}: true {direct} > measured {}",
                    fd.bound()
                );
                assert!(
                    fd.bound() <= fd.guarantee(ell / 2) + 1e-12,
                    "seed {seed} chunk {chunk}: measured {} > guarantee {}",
                    fd.bound(),
                    fd.guarantee(ell / 2)
                );
            }
        }
    }

    #[test]
    fn fd_is_exact_below_capacity() {
        // Fewer than ℓ rows: B is the stream itself (no shrinkage ever).
        let mut rng = Xoshiro256::new(4);
        let a = Mat::gaussian(6, 20, 1.0, &mut rng);
        let mut fd = FrequentDirections::new(8, 20);
        fd.insert(&a);
        fd.compress();
        assert_eq!(fd.bound(), 0.0);
        assert_eq!(fd.sketch(), a);
    }

    #[test]
    fn corange_solve_recovers_qta_exactly_when_sketch_is_square() {
        // With S square (m = rows), SQ is invertible and X = QᵀA exactly.
        let mut rng = Xoshiro256::new(6);
        let a = Mat::gaussian(20, 10, 1.0, &mut rng);
        let q = linalg::orthonormalize(&Mat::gaussian(20, 4, 1.0, &mut rng));
        let s = CounterSketcher::new(20, 20, 13);
        let sq = Sketcher::project(&s, &q);
        let sa = Sketcher::project(&s, &a);
        let x = solve_corange(&sq, &sa);
        let want = matmul_tn(&q, &a);
        assert!(rel_frobenius_error(&want, &x) < 1e-9);
    }

    #[test]
    #[should_panic(expected = "underdetermined")]
    fn corange_solve_rejects_narrow_sketches() {
        let sq = Mat::zeros(3, 5);
        let sa = Mat::zeros(3, 4);
        solve_corange(&sq, &sa);
    }

    #[test]
    fn one_pass_randsvd_recovers_low_rank_streams() {
        let n = 64;
        let rank = 6;
        let a = matrix_with_spectrum(n, Spectrum::LowRankPlusNoise { rank, noise: 1e-3 }, 7);
        for chunk in [9usize, 16, 64] {
            let r = one_pass_randsvd_digital(&a, chunk, rank, 6, 48, 24, 21);
            let rec = linalg::reconstruct(&r.u, &r.s, &r.vt);
            let rel = rel_frobenius_error(&a, &rec);
            assert!(rel < 0.02, "chunk {chunk}: one-pass recovery {rel}");
            assert!(r.fd_bound >= 0.0);
            let fro2: f64 = a.data.iter().map(|v| v * v).sum();
            assert!((r.fro2 - fro2).abs() < 1e-9 * fro2);
        }
    }

    #[test]
    fn one_pass_factors_are_orthonormal() {
        let a = matrix_with_spectrum(40, Spectrum::Exponential { decay: 0.7 }, 8);
        let r = one_pass_randsvd_digital(&a, 8, 6, 6, 36, 16, 23);
        let utu = matmul_tn(&r.u, &r.u);
        assert!(rel_frobenius_error(&Mat::eye(r.u.cols), &utu) < 1e-9);
        for w in r.s.windows(2) {
            assert!(w[0] >= w[1] - 1e-12, "singular values not sorted: {:?}", r.s);
        }
    }
}
