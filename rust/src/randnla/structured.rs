//! Structured fast sketches: SRHT and sparse-sign operators for the host
//! projection arm.
//!
//! The paper's Fig. 2 argument is that dense Gaussian projection is the
//! digital bottleneck; the RandNLA software stack's standard answer is a
//! *structured* transform with the same JL guarantees at a fraction of
//! the flops:
//!
//! - [`SrhtSketcher`] — subsampled randomized Hadamard transform
//!   `S = R · H · D`: Rademacher column signs (D), a fast Walsh–Hadamard
//!   transform over the padded input dimension (H, applied in
//!   O(n log n) per column via [`crate::linalg::fwht`]), and counter-based
//!   row sampling (R). O(k · n log n) per k-column batch instead of
//!   O(k · m · n).
//! - [`SparseSignSketcher`] — `s` nonzero entries of magnitude
//!   `sqrt(m/s)` per input column (CountSketch at `s = 1`), stored in
//!   CSR form so a projection is one O(nnz · k) sparse accumulation.
//!
//! Both follow the repo's Gaussian convention `E[S^T S] = m · I` (rows
//! behave like unnormalised N(0,1) probes), so every estimator that
//! divides by `m` — trace, approximate matmul, triangles — and every
//! range finder (randsvd, nystrom, lstsq, features) works unchanged
//! through the [`Sketcher`] seam.
//!
//! Reproducibility contract (mirrors
//! [`CounterSketcher`](crate::randnla::backend::CounterSketcher)): every
//! sign, sample row and sparse coordinate is a pure Philox function of
//! `(seed, index)`, so shard cells address blocks of *one* logical
//! operator. Output-dim shards are bit-identical to the unsharded
//! projection; input-dim shards recombine to it up to f64 summation
//! association — the same exactness classes the shard planner already
//! guarantees for the counter Gaussian (see rust/src/coordinator/shard.rs).

use std::ops::Range;

use crate::linalg::lowp::{bf16_round, Precision};
use crate::linalg::{fwht_rows, fwht_rows_f32, hadamard_sign, padded_pow2, Mat};
use crate::parallel;
use crate::randnla::backend::Sketcher;
use crate::rng::philox::Philox4x32;

/// Philox counter tag for SRHT column signs (kept far from the
/// row-permutation tags so the two streams never share a counter).
const SRHT_SIGN_TAG: u64 = u64::MAX;
/// Philox counter tag for the row-sampling permutation constants.
const SRHT_PERM_TAG: u64 = u64::MAX - 1;

/// A seeded bijection on `[0, 2^bits)`: three rounds of xor-constant,
/// odd-multiply and xor-shift folding, every step invertible mod
/// 2^bits. Used to sample Hadamard rows *without replacement* while
/// staying a pure function of `(seed, i)` — the counter-addressability
/// the shard planner needs.
struct BitPerm {
    bits: u32,
    muls: [u64; 3],
    xors: [u64; 3],
}

impl BitPerm {
    fn new(key: &Philox4x32, bits: u32) -> Self {
        let mut muls = [1u64; 3];
        let mut xors = [0u64; 3];
        for r in 0..3 {
            let b = key.block_at(SRHT_PERM_TAG, r as u64);
            muls[r] = (((b[0] as u64) << 32) | b[1] as u64) | 1; // odd => invertible
            xors[r] = ((b[2] as u64) << 32) | b[3] as u64;
        }
        Self { bits, muls, xors }
    }

    fn apply(&self, i: u64) -> u64 {
        if self.bits == 0 {
            return 0;
        }
        let mask = (1u64 << self.bits) - 1;
        let shift = (self.bits / 2 + 1).min(self.bits.max(1));
        let mut x = i & mask;
        for r in 0..3 {
            x ^= self.xors[r] & mask;
            x = x.wrapping_mul(self.muls[r]) & mask;
            x ^= x >> shift;
        }
        x & mask
    }
}

/// Subsampled randomized Hadamard transform operator (m x n).
///
/// Entry `S[i, j] = d_j * (-1)^{popcount(r_i & j)}` with `d_j` Rademacher
/// signs and `r_i` rows of the `n_pad = 2^ceil(log2 n)` Hadamard matrix
/// sampled without replacement through a seeded bit-permutation (rows
/// cycle when m > n_pad). Entries are +-1, so `E[S^T S] = m I` like the
/// dense Gaussian convention.
pub struct SrhtSketcher {
    m: usize,
    n: usize,
    n_pad: usize,
    /// Rademacher column signs d_j (Philox, tag [`SRHT_SIGN_TAG`]).
    signs: Vec<f64>,
    /// Sampled Hadamard rows r_i = perm(i mod n_pad).
    rows: Vec<u32>,
}

impl SrhtSketcher {
    pub fn new(m: usize, n: usize, seed: u64) -> Self {
        assert!(m > 0 && n > 0, "SRHT needs positive dims, got {m}x{n}");
        let key = Philox4x32::new(seed);
        let n_pad = padded_pow2(n);
        let signs = (0..n)
            .map(|j| {
                let lane = key.block_at(SRHT_SIGN_TAG, (j / 4) as u64)[j % 4];
                if lane & 1 == 0 {
                    1.0
                } else {
                    -1.0
                }
            })
            .collect();
        let perm = BitPerm::new(&key, n_pad.trailing_zeros());
        let rows = (0..m).map(|i| perm.apply((i % n_pad) as u64) as u32).collect();
        Self { m, n, n_pad, signs, rows }
    }

    /// Padded Hadamard dimension (power of two >= n).
    pub fn n_pad(&self) -> usize {
        self.n_pad
    }

    /// The Hadamard row output row `i` samples (distinct while
    /// `i < n_pad`, cycling after).
    pub fn sampled_row(&self, i: usize) -> usize {
        self.rows[i] as usize
    }

    /// Random access to operator entry (i, j) — used when a shard cell
    /// materialises a block instead of running the fast path.
    #[inline]
    pub fn entry(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.m && j < self.n);
        self.signs[j] * hadamard_sign(self.rows[i] as usize, j)
    }

    /// Materialise the (rows x cols) block of the operator. Blocks of
    /// one seed tile together exactly, like `CounterSketcher::block`.
    pub fn block(&self, rows: Range<usize>, cols: Range<usize>) -> Mat {
        debug_assert!(rows.end <= self.m && cols.end <= self.n);
        Mat::from_fn(rows.len(), cols.len(), |bi, bj| {
            self.entry(rows.start + bi, cols.start + bj)
        })
    }

    /// The full explicit operator (tests / small problems).
    pub fn matrix(&self) -> Mat {
        self.block(0..self.m, 0..self.n)
    }

    /// Fast structured apply of one shard cell: rows `out` of the
    /// operator against input rows `inp` (x holds exactly those rows).
    ///
    /// The cell embeds its input rows at their global positions of the
    /// zero-padded n_pad buffer, so input-dim shards sum to the full
    /// projection by FWHT linearity; output-dim shards read disjoint
    /// sampled rows of the *same* transform and are bit-identical to the
    /// unsharded apply.
    pub fn project_block(&self, out: Range<usize>, inp: Range<usize>, x: &Mat) -> Mat {
        debug_assert!(out.end <= self.m && inp.end <= self.n);
        assert_eq!(x.rows, inp.len(), "cell input rows {} != range {:?}", x.rows, inp);
        let k = x.cols;
        if k == 0 {
            return Mat::zeros(out.len(), 0);
        }
        // Scratch: one row per data column (contiguous butterflies),
        // scaled by the Rademacher signs at the global coordinates.
        let mut buf = Mat::zeros(k, self.n_pad);
        for (li, j) in inp.clone().enumerate() {
            let s = self.signs[j];
            let xrow = x.row(li);
            for (c, &xv) in xrow.iter().enumerate() {
                buf.data[c * self.n_pad + j] = s * xv;
            }
        }
        fwht_rows(&mut buf);
        let mut y = Mat::zeros(out.len(), k);
        for (oi, i) in out.clone().enumerate() {
            let r = self.rows[i] as usize;
            let yrow = y.row_mut(oi);
            for (c, dst) in yrow.iter_mut().enumerate() {
                *dst = buf.at(c, r);
            }
        }
        y
    }

    /// Low-precision fast apply of one shard cell: the input rows are
    /// rounded through the tier's grid (f32, or the bf16 grid for
    /// `Bf16`), the butterfly network runs in f32
    /// ([`fwht_rows_f32`] — signs and Hadamard entries are +-1, exact
    /// in every tier), and the sampled rows widen back to f64.
    ///
    /// `F64` is exactly [`Self::project_block`] — bitwise. The lower
    /// tiers keep the same shard-determinism classes per tier: the
    /// embedding uses global coordinates and each row's butterfly is
    /// sequential, so output-dim shards are bit-identical to the
    /// unsharded tier apply and input-dim shards recombine in f64.
    pub fn project_block_lowp(
        &self,
        out: Range<usize>,
        inp: Range<usize>,
        x: &Mat,
        precision: Precision,
    ) -> Mat {
        if precision == Precision::F64 {
            return self.project_block(out, inp, x);
        }
        debug_assert!(out.end <= self.m && inp.end <= self.n);
        assert_eq!(x.rows, inp.len(), "cell input rows {} != range {:?}", x.rows, inp);
        let k = x.cols;
        if k == 0 {
            return Mat::zeros(out.len(), 0);
        }
        let mut buf = vec![0.0f32; k * self.n_pad];
        for (li, j) in inp.clone().enumerate() {
            let s = self.signs[j] as f32;
            let xrow = x.row(li);
            for (c, &xv) in xrow.iter().enumerate() {
                let v = match precision {
                    Precision::Bf16 => bf16_round(xv as f32),
                    _ => xv as f32,
                };
                buf[c * self.n_pad + j] = s * v;
            }
        }
        fwht_rows_f32(&mut buf, self.n_pad);
        let mut y = Mat::zeros(out.len(), k);
        for (oi, i) in out.clone().enumerate() {
            let r = self.rows[i] as usize;
            let yrow = y.row_mut(oi);
            for (c, dst) in yrow.iter_mut().enumerate() {
                *dst = buf[c * self.n_pad + r] as f64;
            }
        }
        y
    }
}

impl Sketcher for SrhtSketcher {
    fn m(&self) -> usize {
        self.m
    }

    fn n(&self) -> usize {
        self.n
    }

    fn project(&self, a: &Mat) -> Mat {
        assert_eq!(a.rows, self.n, "SRHT input rows {} != n {}", a.rows, self.n);
        self.project_block(0..self.m, 0..self.n, a)
    }

    fn label(&self) -> &'static str {
        "srht"
    }
}

/// Sparse-sign sketching operator (m x n): each input column holds `s`
/// nonzeros of magnitude `sqrt(m/s)` at distinct counter-drawn rows
/// (CountSketch when `s = 1`). `E[S^T S] = m I`, matching the repo's
/// Gaussian scale convention.
///
/// Stored CSR (row-major over output rows) so the apply parallelises
/// over disjoint output bands in O(nnz · k); the per-column definition
/// stays the source of truth, which is what makes input-dim shards
/// (column subsets) exact.
pub struct SparseSignSketcher {
    m: usize,
    n: usize,
    s: usize,
    /// CSR row starts (len m + 1).
    row_ptr: Vec<usize>,
    /// Column index per nonzero, ascending within each row.
    cols: Vec<u32>,
    /// Signed magnitude per nonzero (+- sqrt(m/s)).
    vals: Vec<f64>,
}

impl SparseSignSketcher {
    pub fn new(m: usize, n: usize, s: usize, seed: u64) -> Self {
        assert!(m > 0 && n > 0, "sparse sign needs positive dims, got {m}x{n}");
        assert!((1..=m).contains(&s), "nnz/col {s} must be in 1..={m}");
        let rows_key = Philox4x32::new(seed ^ 0xA5A5_5A5A_0F0F_F0F0);
        let signs_key = Philox4x32::new(seed ^ 0x3C3C_C3C3_69A5_5A96);
        let scale = (m as f64 / s as f64).sqrt();

        // Column-major definition: s distinct rows per column by
        // counter-based rejection (deterministic in (seed, j, draw#)).
        let mut col_rows = vec![0u32; n * s];
        let mut col_vals = vec![0.0f64; n * s];
        for j in 0..n {
            let taken = &mut col_rows[j * s..(j + 1) * s];
            let mut chosen = 0usize;
            let mut ctr = 0u64;
            while chosen < s {
                let block = rows_key.block_at(j as u64, ctr);
                ctr += 1;
                for &w in &block {
                    // Lemire map of the 32-bit word onto [0, m).
                    let r = ((w as u64 * m as u64) >> 32) as u32;
                    if taken[..chosen].contains(&r) {
                        continue;
                    }
                    taken[chosen] = r;
                    chosen += 1;
                    if chosen == s {
                        break;
                    }
                }
            }
            for t in 0..s {
                let lane = signs_key.block_at(j as u64, (t / 4) as u64)[t % 4];
                col_vals[j * s + t] = if lane & 1 == 0 { scale } else { -scale };
            }
        }

        // Convert to CSR; filling in ascending j keeps each row's
        // accumulation order fixed regardless of sharding.
        let mut row_ptr = vec![0usize; m + 1];
        for &r in &col_rows {
            row_ptr[r as usize + 1] += 1;
        }
        for i in 0..m {
            row_ptr[i + 1] += row_ptr[i];
        }
        let mut fill = row_ptr.clone();
        let mut cols = vec![0u32; n * s];
        let mut vals = vec![0.0f64; n * s];
        for j in 0..n {
            for t in 0..s {
                let r = col_rows[j * s + t] as usize;
                cols[fill[r]] = j as u32;
                vals[fill[r]] = col_vals[j * s + t];
                fill[r] += 1;
            }
        }
        Self { m, n, s, row_ptr, cols, vals }
    }

    /// Nonzeros per input column.
    pub fn nnz_per_col(&self) -> usize {
        self.s
    }

    /// Random access to operator entry (i, j) (zero when absent).
    pub fn entry(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.m && j < self.n);
        let lo = self.row_ptr[i];
        let hi = self.row_ptr[i + 1];
        match self.cols[lo..hi].binary_search(&(j as u32)) {
            Ok(at) => self.vals[lo + at],
            Err(_) => 0.0,
        }
    }

    /// Materialise the (rows x cols) block of the operator.
    pub fn block(&self, rows: Range<usize>, cols: Range<usize>) -> Mat {
        debug_assert!(rows.end <= self.m && cols.end <= self.n);
        Mat::from_fn(rows.len(), cols.len(), |bi, bj| {
            self.entry(rows.start + bi, cols.start + bj)
        })
    }

    /// The full explicit operator (tests / small problems).
    pub fn matrix(&self) -> Mat {
        self.block(0..self.m, 0..self.n)
    }

    /// O(nnz · k) apply of one shard cell: output rows `out`, input rows
    /// `inp` (x holds exactly those rows). Parallel over disjoint output
    /// bands; each row accumulates its nonzeros in ascending column
    /// order, so results are thread-count independent and output-dim
    /// shards are bit-identical to the unsharded apply.
    pub fn project_block(&self, out: Range<usize>, inp: Range<usize>, x: &Mat) -> Mat {
        debug_assert!(out.end <= self.m && inp.end <= self.n);
        assert_eq!(x.rows, inp.len(), "cell input rows {} != range {:?}", x.rows, inp);
        let k = x.cols;
        let mut y = Mat::zeros(out.len(), k);
        if k == 0 || out.is_empty() {
            return y;
        }
        const ROWS_PER_TASK: usize = 64;
        let out0 = out.start;
        parallel::par_chunks_mut(&mut y.data, ROWS_PER_TASK * k, |start, band| {
            let first = out0 + start / k;
            let rows_here = band.len() / k;
            for li in 0..rows_here {
                let gi = first + li;
                let yrow = &mut band[li * k..(li + 1) * k];
                for idx in self.row_ptr[gi]..self.row_ptr[gi + 1] {
                    let j = self.cols[idx] as usize;
                    if !inp.contains(&j) {
                        continue;
                    }
                    let v = self.vals[idx];
                    let xrow = x.row(j - inp.start);
                    for (acc, xv) in yrow.iter_mut().zip(xrow) {
                        *acc += v * xv;
                    }
                }
            }
        });
        y
    }

    /// Low-precision apply of one shard cell: operand entries are
    /// rounded through the tier's grid, each product is computed in f32
    /// (operator values are +-1/sqrt(s) — f32-representable scale), and
    /// the per-row accumulation stays in f64 exactly like
    /// [`Self::project_block`], in the same ascending-column order.
    ///
    /// `F64` delegates to [`Self::project_block`] bitwise. Per tier,
    /// the output-dim shard-determinism class is preserved: each output
    /// row's f32 products round identically regardless of banding, and
    /// the f64 accumulation order is fixed.
    pub fn project_block_lowp(
        &self,
        out: Range<usize>,
        inp: Range<usize>,
        x: &Mat,
        precision: Precision,
    ) -> Mat {
        if precision == Precision::F64 {
            return self.project_block(out, inp, x);
        }
        debug_assert!(out.end <= self.m && inp.end <= self.n);
        assert_eq!(x.rows, inp.len(), "cell input rows {} != range {:?}", x.rows, inp);
        let k = x.cols;
        let mut y = Mat::zeros(out.len(), k);
        if k == 0 || out.is_empty() {
            return y;
        }
        const ROWS_PER_TASK: usize = 64;
        let out0 = out.start;
        parallel::par_chunks_mut(&mut y.data, ROWS_PER_TASK * k, |start, band| {
            let first = out0 + start / k;
            let rows_here = band.len() / k;
            for li in 0..rows_here {
                let gi = first + li;
                let yrow = &mut band[li * k..(li + 1) * k];
                for idx in self.row_ptr[gi]..self.row_ptr[gi + 1] {
                    let j = self.cols[idx] as usize;
                    if !inp.contains(&j) {
                        continue;
                    }
                    let v = self.vals[idx] as f32;
                    let xrow = x.row(j - inp.start);
                    for (acc, &xv) in yrow.iter_mut().zip(xrow) {
                        let xt = match precision {
                            Precision::Bf16 => bf16_round(xv as f32),
                            _ => xv as f32,
                        };
                        *acc += (v * xt) as f64;
                    }
                }
            }
        });
        y
    }
}

impl Sketcher for SparseSignSketcher {
    fn m(&self) -> usize {
        self.m
    }

    fn n(&self) -> usize {
        self.n
    }

    fn project(&self, a: &Mat) -> Mat {
        assert_eq!(a.rows, self.n, "sparse-sign input rows {} != n {}", a.rows, self.n);
        self.project_block(0..self.m, 0..self.n, a)
    }

    fn label(&self) -> &'static str {
        "sparse-sign"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{matmul, rel_frobenius_error};
    use crate::parallel::split_ranges;
    use crate::rng::Xoshiro256;

    #[test]
    fn srht_project_matches_explicit_operator() {
        let s = SrhtSketcher::new(12, 37, 7);
        let mut rng = Xoshiro256::new(1);
        let x = Mat::gaussian(37, 5, 1.0, &mut rng);
        let fast = s.project(&x);
        let explicit = matmul(&s.matrix(), &x);
        let rel = rel_frobenius_error(&explicit, &fast);
        assert!(rel < 1e-12, "fast apply drifted from the operator: {rel}");
        assert_eq!(s.label(), "srht");
        assert_eq!((s.m(), s.n()), (12, 37));
        assert_eq!(s.n_pad(), 64);
    }

    #[test]
    fn srht_basis_vectors_read_operator_columns_exactly() {
        // H, D entries are +-1 integers: projecting e_j sums small
        // integers, so the fast path must equal entry() bit for bit.
        let s = SrhtSketcher::new(9, 21, 3);
        for j in [0usize, 1, 7, 20] {
            let e = Mat::from_fn(21, 1, |i, _| if i == j { 1.0 } else { 0.0 });
            let col = s.project(&e);
            for i in 0..9 {
                assert_eq!(col.at(i, 0), s.entry(i, j), "({i},{j})");
            }
        }
    }

    #[test]
    fn srht_blocks_tile_exactly() {
        let s = SrhtSketcher::new(16, 30, 11);
        let full = s.matrix();
        let b = s.block(3..11, 5..23);
        for i in 0..8 {
            for j in 0..18 {
                assert_eq!(b.at(i, j), full.at(3 + i, 5 + j), "({i},{j})");
            }
        }
    }

    #[test]
    fn srht_output_dim_shards_bit_identical() {
        let s = SrhtSketcher::new(24, 50, 5);
        let mut rng = Xoshiro256::new(2);
        let x = Mat::gaussian(50, 3, 1.0, &mut rng);
        let full = s.project(&x);
        for shards in 1..=4 {
            let mut at = 0usize;
            for r in split_ranges(24, shards) {
                let part = s.project_block(r.clone(), 0..50, &x);
                for (bi, i) in r.enumerate() {
                    assert_eq!(part.row(bi), full.row(i), "shards={shards} row {i}");
                }
                at += part.rows;
            }
            assert_eq!(at, 24);
        }
    }

    #[test]
    fn srht_input_dim_shards_sum_to_full() {
        let s = SrhtSketcher::new(16, 40, 9);
        let mut rng = Xoshiro256::new(3);
        let x = Mat::gaussian(40, 4, 1.0, &mut rng);
        let full = s.project(&x);
        for shards in 2..=4 {
            let mut acc = Mat::zeros(16, 4);
            for r in split_ranges(40, shards) {
                let xb = Mat::from_fn(r.len(), 4, |i, j| x.at(r.start + i, j));
                acc = acc.add(&s.project_block(0..16, r, &xb));
            }
            let rel = rel_frobenius_error(&full, &acc);
            assert!(rel < 1e-12, "input shards={shards} drifted {rel}");
        }
    }

    #[test]
    fn srht_samples_rows_without_replacement() {
        // Up to n_pad output rows, every sampled Hadamard row is
        // distinct (the bit-permutation is a bijection); past n_pad the
        // sampling cycles.
        let s = SrhtSketcher::new(64, 60, 17); // n_pad = 64 = m
        let mut seen = vec![false; 64];
        for i in 0..64 {
            let r = s.sampled_row(i);
            assert!(r < 64);
            assert!(!seen[r], "row {r} sampled twice");
            seen[r] = true;
        }
        let wide = SrhtSketcher::new(70, 60, 17);
        assert_eq!(wide.sampled_row(64), wide.sampled_row(0), "cycling past n_pad");
    }

    #[test]
    fn srht_deterministic_by_seed() {
        let a = SrhtSketcher::new(8, 33, 42);
        let b = SrhtSketcher::new(8, 33, 42);
        assert_eq!(a.matrix(), b.matrix());
        let c = SrhtSketcher::new(8, 33, 43);
        assert_ne!(a.matrix(), c.matrix());
    }

    #[test]
    fn srht_gram_matches_gaussian_scale_convention() {
        // E[S^T S] = m I: diagonal entries are exactly m (rows are +-1),
        // and off-diagonals stay small relative to m.
        let m = 512;
        let n = 32;
        let s = SrhtSketcher::new(m, n, 5);
        let g = s.matrix();
        let gtg = crate::linalg::matmul_tn(&g, &g).scale(1.0 / m as f64);
        for i in 0..n {
            assert!((gtg.at(i, i) - 1.0).abs() < 1e-12, "diag {i}: {}", gtg.at(i, i));
        }
        let err = rel_frobenius_error(&Mat::eye(n), &gtg);
        assert!(err < 0.35, "S^T S / m far from I: {err}");
    }

    #[test]
    fn sparse_each_column_has_s_distinct_nonzeros() {
        let m = 24;
        let n = 40;
        let s = 6;
        let sk = SparseSignSketcher::new(m, n, s, 11);
        let g = sk.matrix();
        let scale = (m as f64 / s as f64).sqrt();
        for j in 0..n {
            let nz: Vec<f64> = (0..m).map(|i| g.at(i, j)).filter(|v| *v != 0.0).collect();
            assert_eq!(nz.len(), s, "column {j}");
            for v in &nz {
                assert!((v.abs() - scale).abs() < 1e-12, "column {j} magnitude {v}");
            }
            // Column norm^2 is exactly m: the estimator scale convention.
            let norm2: f64 = nz.iter().map(|v| v * v).sum();
            assert!((norm2 - m as f64).abs() < 1e-9, "column {j} norm2 {norm2}");
        }
    }

    #[test]
    fn sparse_project_matches_explicit_operator() {
        let sk = SparseSignSketcher::new(14, 33, 4, 5);
        let mut rng = Xoshiro256::new(6);
        let x = Mat::gaussian(33, 5, 1.0, &mut rng);
        let fast = sk.project(&x);
        let explicit = matmul(&sk.matrix(), &x);
        let rel = rel_frobenius_error(&explicit, &fast);
        assert!(rel < 1e-12, "sparse apply drifted: {rel}");
        assert_eq!(sk.label(), "sparse-sign");
        assert_eq!(sk.nnz_per_col(), 4);
    }

    #[test]
    fn sparse_shards_recombine() {
        let sk = SparseSignSketcher::new(20, 36, 3, 8);
        let mut rng = Xoshiro256::new(7);
        let x = Mat::gaussian(36, 2, 1.0, &mut rng);
        let full = sk.project(&x);
        // Output-dim: bit-identical stacking.
        for r in split_ranges(20, 3) {
            let part = sk.project_block(r.clone(), 0..36, &x);
            for (bi, i) in r.enumerate() {
                assert_eq!(part.row(bi), full.row(i));
            }
        }
        // Input-dim: exact sum up to f64 association.
        let mut acc = Mat::zeros(20, 2);
        for r in split_ranges(36, 3) {
            let xb = Mat::from_fn(r.len(), 2, |i, j| x.at(r.start + i, j));
            acc = acc.add(&sk.project_block(0..20, r, &xb));
        }
        assert!(rel_frobenius_error(&full, &acc) < 1e-12);
    }

    #[test]
    fn sparse_deterministic_by_seed() {
        let a = SparseSignSketcher::new(10, 25, 3, 99);
        let b = SparseSignSketcher::new(10, 25, 3, 99);
        assert_eq!(a.matrix(), b.matrix());
        let c = SparseSignSketcher::new(10, 25, 3, 100);
        assert_ne!(a.matrix(), c.matrix());
    }

    #[test]
    fn sparse_countsketch_edge_s_equals_one_and_s_equals_m() {
        let cs = SparseSignSketcher::new(8, 20, 1, 1);
        let g = cs.matrix();
        for j in 0..20 {
            let nz = (0..8).filter(|&i| g.at(i, j) != 0.0).count();
            assert_eq!(nz, 1, "countsketch column {j}");
        }
        // Fully dense column: rejection loop must still terminate.
        let dense = SparseSignSketcher::new(4, 6, 4, 2);
        let gd = dense.matrix();
        for j in 0..6 {
            let nz = (0..4).filter(|&i| gd.at(i, j) != 0.0).count();
            assert_eq!(nz, 4, "dense column {j}");
        }
    }

    #[test]
    fn structured_sketchers_preserve_norms_in_expectation() {
        // JL over Philox seeds: E[||Sx||^2 / m] = ||x||^2 for both
        // structured families (quick in-module check; the heavier sweep
        // lives in tests/prop_sketch_stats.rs).
        let n = 48;
        let m = 32;
        let mut rng = Xoshiro256::new(9);
        let x = Mat::gaussian(n, 1, 1.0, &mut rng);
        let x2: f64 = x.data.iter().map(|v| v * v).sum();
        let trials = 60u64;
        let mut srht_acc = 0.0;
        let mut sparse_acc = 0.0;
        for t in 0..trials {
            let sr = SrhtSketcher::new(m, n, 500 + t);
            srht_acc += sr.project(&x).data.iter().map(|v| v * v).sum::<f64>() / m as f64;
            let sp = SparseSignSketcher::new(m, n, 4, 900 + t);
            sparse_acc += sp.project(&x).data.iter().map(|v| v * v).sum::<f64>() / m as f64;
        }
        let srht_mean = srht_acc / trials as f64;
        let sparse_mean = sparse_acc / trials as f64;
        assert!((srht_mean - x2).abs() / x2 < 0.15, "srht JL: {srht_mean} vs {x2}");
        assert!((sparse_mean - x2).abs() / x2 < 0.15, "sparse JL: {sparse_mean} vs {x2}");
    }

    #[test]
    fn lowp_f64_tier_is_bitwise_the_full_precision_apply() {
        let mut rng = Xoshiro256::new(11);
        let x = Mat::gaussian(37, 4, 1.0, &mut rng);
        let sr = SrhtSketcher::new(12, 37, 7);
        assert_eq!(
            sr.project_block(0..12, 0..37, &x),
            sr.project_block_lowp(0..12, 0..37, &x, Precision::F64)
        );
        let sp = SparseSignSketcher::new(12, 37, 4, 7);
        assert_eq!(
            sp.project_block(0..12, 0..37, &x),
            sp.project_block_lowp(0..12, 0..37, &x, Precision::F64)
        );
    }

    #[test]
    fn lowp_tiers_track_f64_within_tier_tolerance() {
        let mut rng = Xoshiro256::new(12);
        let x = Mat::gaussian(100, 6, 1.0, &mut rng);
        let sr = SrhtSketcher::new(24, 100, 5);
        let sp = SparseSignSketcher::new(24, 100, 6, 5);
        for prec in [Precision::F32, Precision::Bf16] {
            // Sketching-scale relative error budget: tier unit roundoff
            // amplified by the transform length / nnz depth.
            let budget = prec.tier_tol() * 40.0;
            let sr_rel = rel_frobenius_error(
                &sr.project_block(0..24, 0..100, &x),
                &sr.project_block_lowp(0..24, 0..100, &x, prec),
            );
            assert!(sr_rel < budget, "srht {prec:?}: {sr_rel} vs {budget}");
            let sp_rel = rel_frobenius_error(
                &sp.project_block(0..24, 0..100, &x),
                &sp.project_block_lowp(0..24, 0..100, &x, prec),
            );
            assert!(sp_rel < budget, "sparse {prec:?}: {sp_rel} vs {budget}");
        }
    }

    #[test]
    fn lowp_output_shards_are_bit_identical_per_tier() {
        // The batcher splits the output dimension into shard cells; a
        // tier's cells must reproduce the unsharded tier apply bitwise
        // so pool size never changes results.
        let mut rng = Xoshiro256::new(13);
        let x = Mat::gaussian(70, 3, 1.0, &mut rng);
        let sr = SrhtSketcher::new(20, 70, 8);
        let sp = SparseSignSketcher::new(20, 70, 4, 8);
        for prec in [Precision::F32, Precision::Bf16] {
            let sr_full = sr.project_block_lowp(0..20, 0..70, &x, prec);
            let sp_full = sp.project_block_lowp(0..20, 0..70, &x, prec);
            for cells in 1..=4usize {
                for r in split_ranges(20, cells) {
                    let sr_cell = sr.project_block_lowp(r.clone(), 0..70, &x, prec);
                    let sp_cell = sp.project_block_lowp(r.clone(), 0..70, &x, prec);
                    for (li, gi) in r.clone().enumerate() {
                        assert_eq!(sr_cell.row(li), sr_full.row(gi), "srht {prec:?} {r:?}");
                        assert_eq!(sp_cell.row(li), sp_full.row(gi), "sparse {prec:?} {r:?}");
                    }
                }
            }
        }
    }
}
