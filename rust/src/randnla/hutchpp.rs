//! Hutch++ trace estimation (Meyer, Musco, Musco & Woodruff 2021) —
//! the variance-reduced successor of plain Hutchinson.
//!
//! Hutchinson needs O(1/eps^2) matvecs for relative error eps because its
//! variance is governed by the *whole* Frobenius norm of A. Hutch++
//! splits the estimate:
//!
//! 1. **head** — find a small range basis Q of A (one sketching pass)
//!    and take `Tr(Q^T A Q)` *exactly* (host algebra, no variance);
//! 2. **residual** — run Hutchinson only on the deflated remainder
//!    `(I - QQ^T) A (I - QQ^T)`, whose Frobenius norm carries just the
//!    tail of A's spectrum.
//!
//! On decaying spectra the tail is tiny, so the probe budget drops from
//! O(1/eps^2) to O(1/eps) — the adaptive-accuracy knob the paper's
//! "negligible precision loss" claim needs to be *controllable* (see
//! `docs/algorithms.md`). Unbiasedness: `Tr(PAP) = Tr(A) - Tr(Q^T A Q)`
//! for the projector `P = I - QQ^T`, so head + residual estimates Tr(A)
//! exactly in expectation, provided the residual probes are independent
//! of the range columns.

use crate::linalg::{self, matmul, matmul_nt, matmul_tn, Mat};
use crate::randnla::backend::{DigitalSketcher, Sketcher};
use crate::randnla::sketch::symmetric_sketch;

/// How a total projection-column budget splits between the range pass
/// and the residual Hutchinson pass.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct HutchPPSplit {
    /// Columns spent finding the range basis Q.
    pub range: usize,
    /// Probe columns spent on the deflated residual.
    pub resid: usize,
}

/// Split a total budget of `m` projection columns. The two halves are
/// deliberately *unequal* (`range < resid`, never tied): through the
/// serving plane each half becomes its own `(n, m)` batch signature, and
/// distinct signatures realise independent operators — the independence
/// the residual pass requires for unbiasedness.
pub fn split_budget(m: usize) -> HutchPPSplit {
    assert!(m >= 3, "hutch++ needs a budget of at least 3 columns, got {m}");
    let range = (m - 1) / 2;
    HutchPPSplit { range, resid: m - range }
}

/// The deflated remainder `(I - QQ^T) A (I - QQ^T)` for orthonormal Q.
pub fn deflate(a: &Mat, q: &Mat) -> Mat {
    assert!(a.is_square(), "deflate needs square A");
    assert_eq!(a.rows, q.rows, "Q rows {} != A dim {}", q.rows, a.rows);
    let aq = matmul(a, q); // n x r
    let qta = matmul_tn(q, a); // r x n
    let qtaq = matmul_tn(q, &aq); // r x r
    // A - Q(Q^T A) - (A Q)Q^T + Q (Q^T A Q) Q^T
    a.sub(&matmul(q, &qta))
        .sub(&matmul_nt(&aq, q))
        .add(&matmul(q, &matmul_nt(&qtaq, q)))
}

/// Hutch++ with explicit arms: `range` supplies the range-finding
/// columns (`range.m()` of them), `resid` the residual probes. The two
/// sketchers **must be statistically independent** (different seeds, or
/// disjoint row blocks of one operator) — correlated probes bias the
/// residual term.
pub fn hutchpp(range: &dyn Sketcher, resid: &dyn Sketcher, a: &Mat) -> f64 {
    assert!(a.is_square(), "hutch++ needs square A");
    assert_eq!(a.rows, range.n(), "A dim {} != range sketcher n {}", a.rows, range.n());
    assert_eq!(a.rows, resid.n(), "A dim {} != resid sketcher n {}", a.rows, resid.n());
    // Range pass: Y = A Omega with Omega = G^T — the device projects A^T
    // (exactly the randsvd offload, see randsvd.rs).
    let y = range.project(&a.transpose()).transpose();
    let q = linalg::orthonormalize(&y);
    // Head: exact trace of the compressed block (no variance).
    let head = matmul_tn(&q, &matmul(a, &q)).trace();
    // Residual: plain Hutchinson on the deflated remainder.
    head + symmetric_sketch(resid, &deflate(a, &q)).trace()
}

/// Budget-driven digital-arm Hutch++: split `m` columns via
/// [`split_budget`] and seed two independent host sketchers. The
/// comparison harness tests and `benches/adaptive.rs` use this to grade
/// Hutch++ against [`hutchinson`](crate::randnla::hutchinson) at equal
/// column budgets.
pub fn hutchpp_digital(a: &Mat, m: usize, seed: u64) -> f64 {
    let split = split_budget(m);
    let range = DigitalSketcher::new(split.range, a.rows, seed);
    let resid = DigitalSketcher::new(split.resid, a.rows, seed ^ 0x9E37_79B9_7F4A_7C15);
    hutchpp(&range, &resid, a)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::randnla::trace::hutchinson;
    use crate::workload::{psd_with_spectrum, Spectrum};

    #[test]
    fn split_covers_budget_with_distinct_halves() {
        for m in 3..64 {
            let s = split_budget(m);
            assert_eq!(s.range + s.resid, m, "budget {m} not covered");
            assert!(s.range >= 1, "empty range at m={m}");
            assert!(s.resid >= 1, "empty resid at m={m}");
            assert_ne!(s.range, s.resid, "signature collision at m={m}");
        }
    }

    #[test]
    #[should_panic(expected = "at least 3")]
    fn tiny_budget_rejected() {
        split_budget(2);
    }

    #[test]
    fn deflation_is_orthogonal_to_the_basis() {
        // Q^T (PAP) = 0 and (PAP) Q = 0 by construction.
        let a = psd_with_spectrum(32, Spectrum::Exponential { decay: 0.8 }, 1);
        let s = DigitalSketcher::new(6, 32, 2);
        let q = linalg::orthonormalize(&s.project(&a.transpose()).transpose());
        let d = deflate(&a, &q);
        let left = matmul_tn(&q, &d);
        let right = matmul(&d, &q);
        assert!(crate::linalg::max_abs(&left) < 1e-10, "Q^T PAP != 0");
        assert!(crate::linalg::max_abs(&right) < 1e-10, "PAP Q != 0");
    }

    #[test]
    fn exact_when_range_spans_everything() {
        // With a full-rank basis the head is Tr(A) and the residual is 0,
        // whatever the probes do.
        let n = 12;
        let a = psd_with_spectrum(n, Spectrum::Exponential { decay: 0.5 }, 3);
        let range = DigitalSketcher::new(n, n, 4);
        let resid = DigitalSketcher::new(3, n, 5);
        let est = hutchpp(&range, &resid, &a);
        assert!((est - a.trace()).abs() / a.trace() < 1e-9, "{est} vs {}", a.trace());
    }

    #[test]
    fn unbiased_over_seeds() {
        let a = psd_with_spectrum(40, Spectrum::Exponential { decay: 0.85 }, 6);
        let truth = a.trace();
        let trials = 200u64;
        let mean = (0..trials)
            .map(|t| hutchpp_digital(&a, 12, 9_000 + t))
            .sum::<f64>()
            / trials as f64;
        let rel = (mean - truth).abs() / truth;
        assert!(rel < 0.02, "hutch++ bias {rel}");
    }

    #[test]
    fn beats_hutchinson_at_equal_budget() {
        // Same column budget, decaying spectrum: the deflated residual
        // carries only the spectral tail, so Hutch++'s error must be
        // smaller in RMS over seeds.
        let a = psd_with_spectrum(48, Spectrum::Exponential { decay: 0.8 }, 7);
        let truth = a.trace();
        let trials = 24u64;
        let m = 24;
        let mut sq_pp = 0.0;
        let mut sq_h = 0.0;
        for t in 0..trials {
            let e_pp = hutchpp_digital(&a, m, 500 + t) - truth;
            let s = DigitalSketcher::new(m, 48, 7_700 + t);
            let e_h = hutchinson(&s, &a) - truth;
            sq_pp += e_pp * e_pp;
            sq_h += e_h * e_h;
        }
        assert!(
            sq_pp < sq_h,
            "hutch++ rms {} !< hutchinson rms {}",
            (sq_pp / trials as f64).sqrt() / truth,
            (sq_h / trials as f64).sqrt() / truth
        );
    }
}
