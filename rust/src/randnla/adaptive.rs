//! Incremental rangefinder — blocked randQB with an a-posteriori error
//! gate (Halko, Martinsson & Tropp 2011 §4.3 / Yu, Gu & Li 2018).
//!
//! The fixed-size rangefinder in [`randsvd`](crate::randnla::randsvd())
//! takes a sketch size `k` and hopes. This module makes accuracy the
//! input instead: grow an orthonormal basis Q block by block until the
//! *measured* residual `||A - QQ^T A||_F / ||A||_F` falls below a target
//! tolerance. The gate is exact and cheap: for orthonormal Q,
//! `||A - QQ^T A||_F^2 = ||A||_F^2 - ||Q^T A||_F^2`, and `B = Q^T A` is
//! maintained incrementally anyway (it is the matrix the small SVD runs
//! on).
//!
//! Each pass draws a *fresh, independent* Gaussian block. Through the
//! serving plane this falls out of the ladder convention encoded in
//! [`block_width`]: pass `i` projects `block + i` columns, so every pass
//! addresses a distinct `(n, width)` batch signature — a distinct
//! signature-seeded operator — without plumbing any salt through the
//! batcher, and every pass stays on the existing sketch/shard plane
//! (OPU, SRHT, sparse and dense arms all get adaptivity for free).
//!
//! [`IncrementalRange`] is the driver-agnostic core: callers feed it
//! range blocks (`Y = A·Omega_pass`) and read the gate; the coordinator
//! parks the growing basis in its operand store between passes (see
//! `coordinator/server.rs`). [`adaptive_range`] is the in-process
//! convenience loop over a block-drawing closure.

use crate::linalg::{self, frobenius, matmul, matmul_tn, Mat};
use crate::randnla::backend::DigitalSketcher;

/// Options for [`adaptive_range`].
#[derive(Clone, Copy, Debug)]
pub struct RangeFinderOpts {
    /// Base block size of the ladder (pass `i` draws `block + i` columns,
    /// see [`block_width`]).
    pub block: usize,
    /// Hard cap on the basis size (the budget the caller is willing to
    /// pay when the gate never passes).
    pub max_rank: usize,
    /// Target relative residual `||A - QQ^T A||_F / ||A||_F`.
    pub tol: f64,
}

impl Default for RangeFinderOpts {
    fn default() -> Self {
        Self { block: 8, max_rank: 64, tol: 1e-2 }
    }
}

/// Width of pass `pass` of the rangefinder ladder. Widths grow by one
/// per pass so that, through the serving plane, every pass projects a
/// *distinct* `(n, width)` signature — i.e. a fresh independent operator
/// — while in-process callers simply use it as a block-size schedule.
pub fn block_width(block: usize, pass: usize) -> usize {
    block.max(1) + pass
}

/// What the rangefinder found.
pub struct RangeFindResult {
    /// Orthonormal basis of (an approximation of) A's column space.
    pub q: Mat,
    /// `B = Q^T A`, maintained incrementally — feed it straight to the
    /// small SVD (no recompute) when no power iterations follow.
    pub b: Mat,
    /// Measured relative residual `||A - QQ^T A||_F / ||A||_F`.
    pub rel_err: f64,
    /// `||A||_F^2`, fixed over the run — exposed so rank selection
    /// never rescans A.
    pub fro2: f64,
    /// The gate's final residual `||A - QQ^T A||_F^2` (valid for this
    /// basis; stale once power iterations move it).
    pub resid2: f64,
    /// Projection passes executed.
    pub passes: usize,
    /// Whether the gate passed (false = the rank cap was hit first).
    pub converged: bool,
}

/// Driver-agnostic incremental rangefinder state: absorb fresh range
/// blocks, read the exact Frobenius error gate.
pub struct IncrementalRange {
    rows: usize,
    q: Option<Mat>,
    b: Option<Mat>,
    /// ||A||_F^2, fixed at construction.
    fro2: f64,
    /// ||Q^T A||_F^2 accumulated over absorbed blocks.
    bn2: f64,
    cap: usize,
    tol: f64,
    passes: usize,
}

impl IncrementalRange {
    /// Start a range find on `a` with basis capped at `cap` columns and
    /// a relative-error target of `tol`. Panics on an all-zero matrix —
    /// serving-path callers that must not panic use
    /// [`try_new`](Self::try_new).
    pub fn new(a: &Mat, cap: usize, tol: f64) -> Self {
        Self::try_new(a, cap, tol).expect("adaptive rangefinder needs a nonzero matrix")
    }

    /// Fallible constructor: `None` when A is all-zero (no column space
    /// to find; a relative tolerance is meaningless).
    pub fn try_new(a: &Mat, cap: usize, tol: f64) -> Option<Self> {
        assert!(
            tol > 0.0 && tol < 1.0,
            "relative tolerance must lie in (0, 1), got {tol}"
        );
        let fro2: f64 = a.data.iter().map(|v| v * v).sum();
        if fro2 <= 0.0 {
            return None;
        }
        Some(Self {
            rows: a.rows,
            q: None,
            b: None,
            fro2,
            bn2: 0.0,
            cap: cap.clamp(1, a.rows),
            tol,
            passes: 0,
        })
    }

    /// Columns in the basis so far.
    pub fn rank(&self) -> usize {
        self.q.as_ref().map_or(0, |q| q.cols)
    }

    /// Passes absorbed so far (the ladder index of the *next* pass).
    pub fn passes(&self) -> usize {
        self.passes
    }

    /// Requested width of the next pass for a given base block size.
    pub fn next_width(&self, block: usize) -> usize {
        block_width(block, self.passes)
    }

    /// Measured relative residual `||A - QQ^T A||_F / ||A||_F`.
    pub fn rel_err(&self) -> f64 {
        ((self.fro2 - self.bn2).max(0.0) / self.fro2).sqrt()
    }

    pub fn converged(&self) -> bool {
        self.rel_err() <= self.tol
    }

    /// True once the gate passed or the rank cap is exhausted.
    pub fn done(&self) -> bool {
        self.converged() || self.rank() >= self.cap
    }

    /// Current basis, if any block has been absorbed.
    pub fn q(&self) -> Option<&Mat> {
        self.q.as_ref()
    }

    /// Absorb one fresh range block `y = A·Omega_pass` (columns iid
    /// Gaussian images, independent of every earlier pass): deflate it
    /// against the current basis (twice, for orthogonality at the
    /// gate's precision), orthonormalize, append, and update the gate.
    /// Returns the number of columns actually added — 0 means the block
    /// was already in the span (caller should stop).
    pub fn absorb(&mut self, a: &Mat, y: Mat) -> usize {
        assert_eq!(y.rows, self.rows, "range block rows {} != A rows {}", y.rows, self.rows);
        self.passes += 1;
        let take = y.cols.min(self.cap - self.rank());
        if take == 0 {
            return 0;
        }
        let mut y = y.crop(y.rows, take);
        if let Some(q) = &self.q {
            // Two-pass block Gram-Schmidt against the existing basis.
            for _ in 0..2 {
                let c = matmul_tn(q, &y);
                y = y.sub(&matmul(q, &c));
            }
        }
        // Drop columns the basis already explains: machine-noise columns
        // would seed spurious (non-orthogonal) directions in the QR.
        let floor = 1e-26 * self.fro2;
        let kept: Vec<usize> = (0..y.cols)
            .filter(|&j| (0..y.rows).map(|i| y.at(i, j) * y.at(i, j)).sum::<f64>() > floor)
            .collect();
        if kept.is_empty() {
            return 0;
        }
        let y = Mat::from_fn(y.rows, kept.len(), |i, j| y.at(i, kept[j]));
        let qi = linalg::orthonormalize(&y);
        let bi = matmul_tn(&qi, a);
        self.bn2 += frobenius(&bi).powi(2);
        self.q = Some(match self.q.take() {
            None => qi,
            Some(q) => hstack(&q, &qi),
        });
        self.b = Some(match self.b.take() {
            None => bi,
            Some(b) => vstack(&b, &bi),
        });
        kept.len()
    }

    /// Finish: package basis, `B = Q^T A` and the gate readings.
    /// Panics if no block was ever absorbed.
    pub fn into_result(self) -> RangeFindResult {
        let converged = self.converged();
        let rel_err = self.rel_err();
        RangeFindResult {
            q: self.q.expect("rangefinder absorbed no blocks"),
            b: self.b.expect("rangefinder absorbed no blocks"),
            rel_err,
            fro2: self.fro2,
            resid2: (self.fro2 - self.bn2).max(0.0),
            passes: self.passes,
            converged,
        }
    }
}

/// Grow an orthonormal basis of A's column space until the error gate
/// passes. `draw(pass, width)` must return a fresh range block
/// `Y = A·Omega_pass` with up to `width` iid Gaussian-image columns,
/// independent across passes (fewer columns — or zero — signal an
/// exhausted source and stop the loop).
pub fn adaptive_range(
    a: &Mat,
    opts: RangeFinderOpts,
    mut draw: impl FnMut(usize, usize) -> Mat,
) -> RangeFindResult {
    let mut inc = IncrementalRange::new(a, opts.max_rank, opts.tol);
    while !inc.done() {
        let width = inc.next_width(opts.block);
        let y = draw(inc.passes(), width);
        if y.cols == 0 || inc.absorb(a, y) == 0 {
            break;
        }
    }
    inc.into_result()
}

/// Host-arm adaptive rangefinder: pass `i` draws its block from a fresh
/// seed-derived [`DigitalSketcher`] of the ladder width.
pub fn adaptive_range_digital(a: &Mat, opts: RangeFinderOpts, seed: u64) -> RangeFindResult {
    adaptive_range(a, opts, |pass, width| {
        let salt = seed ^ 0x9E37_79B9_7F4A_7C15u64.wrapping_mul(pass as u64 + 1);
        let s = DigitalSketcher::new(width, a.cols, salt);
        s.project(&a.transpose()).transpose()
    })
}

/// Smallest rank whose QB-truncation error meets `tol`, given the
/// singular values `s` of `B = Q^T A`, the basis residual
/// `resid2 = ||A - QQ^T A||_F^2` and `fro2 = ||A||_F^2`. Exact:
/// `||A - Q B_k||_F^2 = resid2 + sum_{i>k} s_i^2` (the two terms are
/// orthogonal). Falls back to `max_rank` when no rank qualifies.
pub fn rank_for_tol(s: &[f64], resid2: f64, fro2: f64, tol: f64, max_rank: usize) -> usize {
    if s.is_empty() {
        return 0;
    }
    let cap = max_rank.min(s.len()).max(1);
    let total: f64 = s.iter().map(|v| v * v).sum();
    let target = tol * tol * fro2;
    let mut head = 0.0;
    for k in 1..=cap {
        head += s[k - 1] * s[k - 1];
        if resid2 + (total - head).max(0.0) <= target {
            return k;
        }
    }
    cap
}

fn hstack(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.rows, b.rows);
    Mat::from_fn(a.rows, a.cols + b.cols, |i, j| {
        if j < a.cols {
            a.at(i, j)
        } else {
            b.at(i, j - a.cols)
        }
    })
}

fn vstack(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.cols);
    Mat::from_fn(a.rows + b.rows, a.cols, |i, j| {
        if i < a.rows {
            a.at(i, j)
        } else {
            b.at(i - a.rows, j)
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::rel_frobenius_error;
    use crate::workload::{matrix_with_spectrum, Spectrum};

    /// Direct measurement of ||A - QQ^T A||_F / ||A||_F.
    fn measured_rel_err(a: &Mat, q: &Mat) -> f64 {
        let proj = matmul(q, &matmul_tn(q, a));
        rel_frobenius_error(a, &proj)
    }

    #[test]
    fn ladder_widths_are_distinct_and_grow() {
        let mut seen = std::collections::HashSet::new();
        for pass in 0..32 {
            assert!(seen.insert(block_width(8, pass)), "width collision at pass {pass}");
        }
        assert_eq!(block_width(0, 0), 1, "zero block clamps to 1");
    }

    #[test]
    fn gate_matches_direct_measurement() {
        // The cheap gate ||A||^2 - ||B||^2 must agree with the directly
        // measured projection residual at every pass.
        let a = matrix_with_spectrum(48, Spectrum::Exponential { decay: 0.8 }, 1);
        let mut inc = IncrementalRange::new(&a, 32, 1e-12);
        for pass in 0..4u64 {
            let s = DigitalSketcher::new(6, 48, 100 + pass);
            inc.absorb(&a, s.project(&a.transpose()).transpose());
            let direct = measured_rel_err(&a, inc.q().unwrap());
            assert!(
                (inc.rel_err() - direct).abs() < 1e-9,
                "gate {} vs direct {direct} after pass {pass}",
                inc.rel_err()
            );
        }
    }

    #[test]
    fn converges_on_low_rank_and_true_error_meets_tol() {
        let a = matrix_with_spectrum(64, Spectrum::LowRankPlusNoise { rank: 8, noise: 1e-3 }, 2);
        let tol = 0.05;
        let r = adaptive_range_digital(
            &a,
            RangeFinderOpts { block: 4, max_rank: 48, tol },
            7,
        );
        assert!(r.converged, "gate never passed (rel {})", r.rel_err);
        assert!(r.q.cols < 24, "no adaptivity: used {} columns", r.q.cols);
        let direct = measured_rel_err(&a, &r.q);
        assert!(direct <= tol, "true error {direct} > tol {tol}");
        assert!(r.passes >= 2, "should take multiple blocks");
    }

    #[test]
    fn cap_stops_unconverged_flat_spectra() {
        // A flat spectrum cannot be compressed: the cap must end the
        // loop with converged = false and an honest error reading.
        let a = matrix_with_spectrum(32, Spectrum::Polynomial { power: 0.1 }, 3);
        let r = adaptive_range_digital(
            &a,
            RangeFinderOpts { block: 4, max_rank: 8, tol: 1e-3 },
            9,
        );
        assert!(!r.converged);
        assert_eq!(r.q.cols, 8, "cap not respected");
        assert!(r.rel_err > 1e-3);
    }

    #[test]
    fn basis_stays_orthonormal_across_blocks() {
        let a = matrix_with_spectrum(40, Spectrum::Exponential { decay: 0.7 }, 4);
        let r = adaptive_range_digital(
            &a,
            RangeFinderOpts { block: 5, max_rank: 30, tol: 1e-6 },
            11,
        );
        let qtq = matmul_tn(&r.q, &r.q);
        let err = rel_frobenius_error(&Mat::eye(r.q.cols), &qtq);
        assert!(err < 1e-9, "basis drifted from orthonormal: {err}");
        // And B really is Q^T A.
        assert!(rel_frobenius_error(&matmul_tn(&r.q, &a), &r.b) < 1e-12);
    }

    #[test]
    fn try_new_refuses_zero_matrices_and_result_carries_gate_readings() {
        assert!(IncrementalRange::try_new(&Mat::zeros(4, 4), 4, 0.1).is_none());
        let a = matrix_with_spectrum(32, Spectrum::Exponential { decay: 0.7 }, 6);
        let r = adaptive_range_digital(
            &a,
            RangeFinderOpts { block: 4, max_rank: 24, tol: 0.05 },
            13,
        );
        // fro2/resid2 are consistent with the reported relative error —
        // callers can reuse them instead of rescanning A.
        let fro2: f64 = a.data.iter().map(|v| v * v).sum();
        assert!((r.fro2 - fro2).abs() < 1e-9 * fro2, "{} vs {fro2}", r.fro2);
        let rel_from_fields = (r.resid2 / r.fro2).sqrt();
        assert!((rel_from_fields - r.rel_err).abs() < 1e-12);
    }

    #[test]
    fn rank_for_tol_picks_the_smallest_sufficient_rank() {
        // Spectrum 4, 2, 1, 0.1 with no basis residual; fro2 = sum s^2.
        let s = [4.0, 2.0, 1.0, 0.1];
        let fro2: f64 = s.iter().map(|v| v * v).sum();
        // Tail after k=2 is 1.01; tol^2*fro2 must exceed it for k=2.
        let tol = (1.02f64 / fro2).sqrt();
        assert_eq!(rank_for_tol(&s, 0.0, fro2, tol, 4), 2);
        // Impossible tolerance falls back to the cap.
        assert_eq!(rank_for_tol(&s, 1.0, fro2, 1e-9, 3), 3);
        // Everything passes at a loose tolerance with one rank.
        assert_eq!(rank_for_tol(&s, 0.0, fro2, 0.9, 4), 1);
    }
}
