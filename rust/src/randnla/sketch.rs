//! The optical sketching arm + symmetric-sketch helpers shared by the
//! trace / triangle estimators.

use std::sync::Arc;

use crate::linalg::{matmul_nt, Mat};
use crate::opu::OpuDevice;
use crate::randnla::backend::Sketcher;

/// Photonic sketcher: projections run on the simulated OPU in holographic
/// linear mode (bit-plane encoded, noisy, quantized — the full chain).
pub struct OpuSketcher {
    device: Arc<OpuDevice>,
}

impl OpuSketcher {
    pub fn new(device: Arc<OpuDevice>) -> Self {
        Self { device }
    }

    pub fn device(&self) -> &OpuDevice {
        &self.device
    }
}

impl Sketcher for OpuSketcher {
    fn m(&self) -> usize {
        self.device.cfg.m
    }

    fn n(&self) -> usize {
        self.device.cfg.n
    }

    fn project(&self, a: &Mat) -> Mat {
        self.device.project(a)
    }

    fn label(&self) -> &'static str {
        "opu"
    }
}

/// B = (G A G^T) / m using two passes of the *same* sketcher — the shared
/// core of Hutchinson and triangle estimation. `a` must be n x n.
pub fn symmetric_sketch(sketcher: &dyn Sketcher, a: &Mat) -> Mat {
    assert!(a.is_square(), "symmetric_sketch needs square A");
    assert_eq!(a.rows, sketcher.n(), "A dim {} != sketcher n {}", a.rows, sketcher.n());
    let m = sketcher.m() as f64;
    // S = G A  (m x n)
    let s = sketcher.project(a);
    // B = S G^T = (G S^T)^T  (m x m)
    let gst = sketcher.project(&s.transpose());
    gst.transpose().scale(1.0 / m)
}

/// Symmetric sketch for an explicit digital G (reference path used by
/// tests and the exact-G ablation): B = G A G^T / m.
pub fn symmetric_sketch_explicit(g: &Mat, a: &Mat) -> Mat {
    let ga = crate::linalg::matmul(g, a);
    matmul_nt(&ga, g).scale(1.0 / g.rows as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::rel_frobenius_error;
    use crate::opu::OpuConfig;
    use crate::randnla::backend::DigitalSketcher;
    use crate::rng::Xoshiro256;

    #[test]
    fn symmetric_sketch_matches_explicit_for_digital() {
        let s = DigitalSketcher::new(16, 32, 1);
        let mut rng = Xoshiro256::new(2);
        let a = Mat::gaussian(32, 32, 1.0, &mut rng).symmetrized();
        let via_trait = symmetric_sketch(&s, &a);
        let explicit = symmetric_sketch_explicit(s.matrix(), &a);
        assert!(rel_frobenius_error(&explicit, &via_trait) < 1e-10);
    }

    #[test]
    fn symmetric_sketch_is_symmetric_for_symmetric_input() {
        let s = DigitalSketcher::new(12, 24, 3);
        let mut rng = Xoshiro256::new(4);
        let a = Mat::gaussian(24, 24, 1.0, &mut rng).symmetrized();
        let b = symmetric_sketch(&s, &a);
        let asym = rel_frobenius_error(&b, &b.transpose());
        assert!(asym < 1e-10, "asymmetry {asym}");
    }

    #[test]
    fn opu_sketcher_close_to_its_oracle() {
        let dev = Arc::new(OpuDevice::new(OpuConfig::ideal(5, 16, 32)));
        let g_oracle = dev.effective_matrix();
        let s = OpuSketcher::new(dev);
        let mut rng = Xoshiro256::new(6);
        let a = Mat::gaussian(32, 8, 1.0, &mut rng);
        let got = s.project(&a);
        let want = crate::linalg::matmul(&g_oracle, &a);
        let rel = rel_frobenius_error(&want, &got);
        assert!(rel < 5e-3, "opu vs oracle: {rel}");
        assert_eq!(s.label(), "opu");
    }

    #[test]
    fn opu_symmetric_sketch_consistent_with_oracle() {
        let dev = Arc::new(OpuDevice::new(OpuConfig::ideal(7, 12, 24)));
        let g = dev.effective_matrix();
        let s = OpuSketcher::new(dev);
        let mut rng = Xoshiro256::new(8);
        let a = Mat::gaussian(24, 24, 0.5, &mut rng).symmetrized();
        let got = symmetric_sketch(&s, &a);
        let want = symmetric_sketch_explicit(&g, &a);
        let rel = rel_frobenius_error(&want, &got);
        assert!(rel < 2e-2, "opu symmetric sketch: {rel}");
    }
}
