//! Randomized SVD — paper §II-C (Halko, Martinsson & Tropp 2011).
//!
//! 1. Range finding: Y = A Omega (Omega = G^T from the sketcher, so the
//!    OPU performs the randomization step: Y^T = G A^T).
//! 2. Optional power iterations with re-orthonormalisation.
//! 3. Q = thinQR(Y); small exact SVD of Q^T A; left factor lifted by Q.

use crate::linalg::{self, matmul, matmul_tn, Mat};
use crate::randnla::adaptive::{rank_for_tol, IncrementalRange};
use crate::randnla::backend::Sketcher;

/// RandSVD output: rank-k factors, singular values descending.
pub struct RandSvd {
    pub u: Mat,
    pub s: Vec<f64>,
    pub vt: Mat,
    /// Columns actually used for the range (<= rank + oversample; fewer
    /// when an adaptive `tol` stopped the rangefinder early).
    pub l: usize,
}

/// Options for the decomposition.
#[derive(Clone, Copy, Debug)]
pub struct RandSvdOpts {
    /// Target rank — in adaptive mode (`tol` set) the *maximum* rank.
    pub rank: usize,
    pub oversample: usize,
    /// Power iterations (q in HMT); 0 is plain range finding.
    pub power_iters: usize,
    /// Adaptive accuracy: when set, the range basis grows in blocks of
    /// [`block`](Self::block) columns until the measured relative
    /// reconstruction error `||A - QQ^T A||_F / ||A||_F` falls below
    /// this (rank + oversample caps the budget), and the returned rank
    /// is the smallest that still meets it. `None` keeps the classic
    /// fixed-size range find.
    pub tol: Option<f64>,
    /// Block size of the adaptive rangefinder (ignored without `tol`).
    pub block: usize,
}

impl Default for RandSvdOpts {
    fn default() -> Self {
        Self { rank: 16, oversample: 8, power_iters: 2, tol: None, block: 8 }
    }
}

/// Compute a rank-`opts.rank` approximate SVD of `a` (n x n or rectangular
/// with rows = sketcher.n()). The sketcher must have m >= rank+oversample;
/// its first l rows are used as Omega^T.
///
/// With `opts.tol` set, rank selection is adaptive: the basis consumes
/// the projection's columns in rangefinder blocks until the exact
/// Frobenius error gate passes (row-slices of one Gaussian operator are
/// iid, so the blocks are fresh), and the returned rank is the smallest
/// meeting the tolerance. The algorithm layer pays one device pass of
/// the full budget either way; the serving plane's `RandSvd { tol }` job
/// instead grows pass by pass and only pays for the columns it uses
/// (see `coordinator/server.rs`).
pub fn randsvd(sketcher: &dyn Sketcher, a: &Mat, opts: RandSvdOpts) -> RandSvd {
    let cap = opts.rank + opts.oversample;
    assert!(cap <= sketcher.m(), "sketcher m {} < rank+oversample {cap}", sketcher.m());
    assert_eq!(
        a.cols,
        sketcher.n(),
        "A cols {} != sketcher n {} (the sketch contracts A's columns)",
        a.cols,
        sketcher.n()
    );

    // Y = A Omega with Omega = G^T (n x m): the device computes G A^T
    // (= Y^T), so the *randomization* step is one OPU/PJRT projection of
    // A^T — exactly the offload the paper proposes. Keep cap columns.
    let yt = sketcher.project(&a.transpose()); // (m x a.rows)
    let y_full = yt.transpose(); // (a.rows x m)
    let y_full = y_full.crop(y_full.rows, cap.min(y_full.cols));

    // `gate` carries the rangefinder's (tol, ||A||^2, resid^2) readings
    // so rank selection never rescans the operand.
    let (mut q, mut range_b, gate) = match opts.tol {
        None => (linalg::orthonormalize(&y_full), None, None),
        Some(tol) => {
            let mut inc = IncrementalRange::new(a, cap, tol);
            let mut used = 0usize;
            while !inc.done() && used < y_full.cols {
                let width = inc.next_width(opts.block).min(y_full.cols - used);
                let block = y_full.col_slice(used, width);
                used += width;
                if inc.absorb(a, block) == 0 {
                    break;
                }
            }
            let res = inc.into_result();
            let gate = Some((tol, res.fro2, res.resid2));
            (res.q, Some(res.b), gate)
        }
    };

    // Power iterations with re-orth: Y <- A (A^T Q(Y)).
    for _ in 0..opts.power_iters {
        let z = matmul_tn(a, &q); // A^T Q
        let qz = linalg::orthonormalize(&z);
        let w = matmul(a, &qz); // A Q(Z)
        q = linalg::orthonormalize(&w);
        range_b = None; // the basis moved: Q^T A must be recomputed
    }

    // Small exact SVD in the compressed space.
    let b = match range_b {
        Some(b) => b,
        None => matmul_tn(&q, a), // (l x cols)
    };
    let l = q.cols;
    let linalg::Svd { u: ub, s, vt } = linalg::svd(&b);
    let u = matmul(&q, &ub);

    let k = match gate {
        None => opts.rank.min(s.len()),
        // Smallest rank meeting the tolerance, exactly:
        // ||A - Q B_k||^2 = (||A||^2 - ||B||^2) + tail_k(s)^2. The
        // gate's residual is reused unless power iterations moved the
        // basis (then only B is rescanned; ||A||^2 never changes).
        Some((tol, fro2, gate_resid2)) => {
            let resid2 = if opts.power_iters == 0 {
                gate_resid2
            } else {
                let bn2: f64 = b.data.iter().map(|v| v * v).sum();
                (fro2 - bn2).max(0.0)
            };
            rank_for_tol(&s, resid2, fro2, tol, opts.rank)
        }
    };
    RandSvd {
        u: u.crop(u.rows, k),
        s: s[..k].to_vec(),
        vt: vt.crop(k, vt.cols),
        l,
    }
}

/// Rank-k reconstruction from the factors.
pub fn reconstruct(r: &RandSvd) -> Mat {
    linalg::reconstruct(&r.u, &r.s, &r.vt)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::rel_frobenius_error;
    use crate::randnla::backend::DigitalSketcher;
    use crate::workload::{matrix_with_spectrum, Spectrum};

    fn low_rank(n: usize, rank: usize, seed: u64) -> Mat {
        matrix_with_spectrum(n, Spectrum::LowRankPlusNoise { rank, noise: 1e-3 }, seed)
    }

    #[test]
    fn recovers_low_rank_matrix() {
        let n = 64;
        let a = low_rank(n, 8, 1);
        let s = DigitalSketcher::new(24, n, 2);
        let opts = RandSvdOpts { rank: 8, oversample: 8, power_iters: 2, ..Default::default() };
        let r = randsvd(&s, &a, opts);
        let rec = reconstruct(&r);
        let rel = rel_frobenius_error(&a, &rec);
        assert!(rel < 0.02, "low-rank recovery: {rel}");
    }

    #[test]
    fn singular_values_match_exact() {
        let n = 48;
        let a = matrix_with_spectrum(n, Spectrum::Exponential { decay: 0.7 }, 3);
        let exact = linalg::svd(&a).s;
        let s = DigitalSketcher::new(32, n, 4);
        let opts = RandSvdOpts { rank: 10, oversample: 10, power_iters: 2, ..Default::default() };
        let r = randsvd(&s, &a, opts);
        for i in 0..6 {
            let rel = (r.s[i] - exact[i]).abs() / exact[i];
            assert!(rel < 0.05, "sigma_{i}: {} vs {} ({rel})", r.s[i], exact[i]);
        }
    }

    #[test]
    fn factors_are_orthonormal() {
        let n = 40;
        let a = low_rank(n, 6, 5);
        let s = DigitalSketcher::new(20, n, 6);
        let opts = RandSvdOpts { rank: 6, oversample: 6, power_iters: 1, ..Default::default() };
        let r = randsvd(&s, &a, opts);
        let utu = matmul_tn(&r.u, &r.u);
        assert!(rel_frobenius_error(&Mat::eye(6), &utu) < 1e-9);
        let vvt = matmul(&r.vt, &r.vt.transpose());
        assert!(rel_frobenius_error(&Mat::eye(6), &vvt) < 1e-9);
    }

    #[test]
    fn power_iterations_help_flat_spectra() {
        let n = 64;
        let a = matrix_with_spectrum(n, Spectrum::Polynomial { power: 0.5 }, 7);
        let err = |q: usize| {
            let s = DigitalSketcher::new(24, n, 100 + q as u64);
            let r = randsvd(
                &s,
                &a,
                RandSvdOpts { rank: 8, oversample: 8, power_iters: q, ..Default::default() },
            );
            let rec = reconstruct(&r);
            // Compare against the optimal rank-8 truncation.
            let best = linalg::truncated(&a, 8);
            rel_frobenius_error(&best, &rec)
        };
        let e0 = err(0);
        let e3 = err(3);
        assert!(e3 < e0, "power iters did not help: {e0} -> {e3}");
    }

    #[test]
    fn near_optimal_vs_eckart_young() {
        let n = 56;
        let a = matrix_with_spectrum(n, Spectrum::Exponential { decay: 0.8 }, 9);
        let k = 8;
        let best_err = rel_frobenius_error(&a, &linalg::truncated(&a, k));
        let s = DigitalSketcher::new(32, n, 10);
        let opts = RandSvdOpts { rank: k, oversample: 12, power_iters: 2, ..Default::default() };
        let r = randsvd(&s, &a, opts);
        let rand_err = rel_frobenius_error(&a, &reconstruct(&r));
        assert!(
            rand_err < 1.3 * best_err + 1e-9,
            "randsvd {rand_err} vs optimal {best_err}"
        );
    }

    #[test]
    fn adaptive_tol_meets_target_and_stops_early() {
        let n = 64;
        let a = low_rank(n, 8, 21);
        let tol = 0.05;
        let s = DigitalSketcher::new(32, n, 22);
        let r = randsvd(
            &s,
            &a,
            RandSvdOpts {
                rank: 24,
                oversample: 8,
                power_iters: 0,
                tol: Some(tol),
                block: 4,
            },
        );
        // The gate stopped the rangefinder well before the 32-column cap
        // and the tolerance picked the rank.
        assert!(r.l < 24, "no adaptivity: used {} columns", r.l);
        assert!(r.s.len() >= 8, "rank {} lost the signal", r.s.len());
        assert!(r.s.len() < 24, "rank selection did not engage");
        let rel = rel_frobenius_error(&a, &reconstruct(&r));
        assert!(rel <= tol, "measured error {rel} > tol {tol}");
    }

    #[test]
    fn adaptive_tol_with_power_iters_still_meets_tol() {
        let n = 48;
        let a = matrix_with_spectrum(n, Spectrum::Exponential { decay: 0.7 }, 23);
        let tol = 0.1;
        let s = DigitalSketcher::new(32, n, 24);
        let r = randsvd(
            &s,
            &a,
            RandSvdOpts { rank: 20, oversample: 8, power_iters: 2, tol: Some(tol), block: 4 },
        );
        let rel = rel_frobenius_error(&a, &reconstruct(&r));
        assert!(rel <= tol, "measured error {rel} > tol {tol}");
        assert!(r.s.len() <= 20);
    }

    #[test]
    fn adaptive_cap_bounds_the_budget_on_flat_spectra() {
        // A near-flat spectrum cannot meet a tight tolerance: the basis
        // must stop at the rank+oversample cap instead of running away.
        let n = 40;
        let a = matrix_with_spectrum(n, Spectrum::Polynomial { power: 0.1 }, 25);
        let s = DigitalSketcher::new(16, n, 26);
        let r = randsvd(
            &s,
            &a,
            RandSvdOpts { rank: 12, oversample: 4, power_iters: 0, tol: Some(1e-6), block: 4 },
        );
        assert_eq!(r.l, 16, "cap not respected: {} columns", r.l);
        assert_eq!(r.s.len(), 12, "falls back to max rank");
    }

    #[test]
    #[should_panic(expected = "rank+oversample")]
    fn rejects_undersized_sketcher() {
        let a = low_rank(32, 4, 11);
        let s = DigitalSketcher::new(8, 32, 12);
        let opts = RandSvdOpts { rank: 8, oversample: 8, power_iters: 0, ..Default::default() };
        randsvd(&s, &a, opts);
    }
}
