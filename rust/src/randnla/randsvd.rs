//! Randomized SVD — paper §II-C (Halko, Martinsson & Tropp 2011).
//!
//! 1. Range finding: Y = A Omega (Omega = G^T from the sketcher, so the
//!    OPU performs the randomization step: Y^T = G A^T).
//! 2. Optional power iterations with re-orthonormalisation.
//! 3. Q = thinQR(Y); small exact SVD of Q^T A; left factor lifted by Q.

use crate::linalg::{self, matmul, matmul_tn, Mat};
use crate::randnla::backend::Sketcher;

/// RandSVD output: rank-k factors, singular values descending.
pub struct RandSvd {
    pub u: Mat,
    pub s: Vec<f64>,
    pub vt: Mat,
    /// Columns actually used for the range (k + oversample).
    pub l: usize,
}

/// Options for the decomposition.
#[derive(Clone, Copy, Debug)]
pub struct RandSvdOpts {
    pub rank: usize,
    pub oversample: usize,
    /// Power iterations (q in HMT); 0 is plain range finding.
    pub power_iters: usize,
}

impl Default for RandSvdOpts {
    fn default() -> Self {
        Self { rank: 16, oversample: 8, power_iters: 2 }
    }
}

/// Compute a rank-`opts.rank` approximate SVD of `a` (n x n or rectangular
/// with rows = sketcher.n()). The sketcher must have m >= rank+oversample;
/// its first l rows are used as Omega^T.
pub fn randsvd(sketcher: &dyn Sketcher, a: &Mat, opts: RandSvdOpts) -> RandSvd {
    let l = opts.rank + opts.oversample;
    assert!(l <= sketcher.m(), "sketcher m {} < rank+oversample {l}", sketcher.m());
    assert_eq!(
        a.cols,
        sketcher.n(),
        "A cols {} != sketcher n {} (the sketch contracts A's columns)",
        a.cols,
        sketcher.n()
    );

    // Y = A Omega with Omega = G^T (n x m): the device computes G A^T
    // (= Y^T), so the *randomization* step is one OPU/PJRT projection of
    // A^T — exactly the offload the paper proposes. Keep l columns.
    let yt = sketcher.project(&a.transpose()); // (m x a.rows)
    let y_full = yt.transpose(); // (a.rows x m)
    let y = y_full.crop(y_full.rows, l.min(y_full.cols));

    // Power iterations with re-orth: Y <- A (A^T Q(Y)).
    let mut q = linalg::orthonormalize(&y);
    for _ in 0..opts.power_iters {
        let z = matmul_tn(a, &q); // A^T Q
        let qz = linalg::orthonormalize(&z);
        let w = matmul(a, &qz); // A Q(Z)
        q = linalg::orthonormalize(&w);
    }

    // Small exact SVD in the compressed space.
    let b = matmul_tn(&q, a); // (l x cols)
    let linalg::Svd { u: ub, s, vt } = linalg::svd(&b);
    let u = matmul(&q, &ub);

    let k = opts.rank.min(s.len());
    RandSvd {
        u: u.crop(u.rows, k),
        s: s[..k].to_vec(),
        vt: vt.crop(k, vt.cols),
        l,
    }
}

/// Rank-k reconstruction from the factors.
pub fn reconstruct(r: &RandSvd) -> Mat {
    linalg::reconstruct(&r.u, &r.s, &r.vt)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::rel_frobenius_error;
    use crate::randnla::backend::DigitalSketcher;
    use crate::workload::{matrix_with_spectrum, Spectrum};

    fn low_rank(n: usize, rank: usize, seed: u64) -> Mat {
        matrix_with_spectrum(n, Spectrum::LowRankPlusNoise { rank, noise: 1e-3 }, seed)
    }

    #[test]
    fn recovers_low_rank_matrix() {
        let n = 64;
        let a = low_rank(n, 8, 1);
        let s = DigitalSketcher::new(24, n, 2);
        let r = randsvd(&s, &a, RandSvdOpts { rank: 8, oversample: 8, power_iters: 2 });
        let rec = reconstruct(&r);
        let rel = rel_frobenius_error(&a, &rec);
        assert!(rel < 0.02, "low-rank recovery: {rel}");
    }

    #[test]
    fn singular_values_match_exact() {
        let n = 48;
        let a = matrix_with_spectrum(n, Spectrum::Exponential { decay: 0.7 }, 3);
        let exact = linalg::svd(&a).s;
        let s = DigitalSketcher::new(32, n, 4);
        let r = randsvd(&s, &a, RandSvdOpts { rank: 10, oversample: 10, power_iters: 2 });
        for i in 0..6 {
            let rel = (r.s[i] - exact[i]).abs() / exact[i];
            assert!(rel < 0.05, "sigma_{i}: {} vs {} ({rel})", r.s[i], exact[i]);
        }
    }

    #[test]
    fn factors_are_orthonormal() {
        let n = 40;
        let a = low_rank(n, 6, 5);
        let s = DigitalSketcher::new(20, n, 6);
        let r = randsvd(&s, &a, RandSvdOpts { rank: 6, oversample: 6, power_iters: 1 });
        let utu = matmul_tn(&r.u, &r.u);
        assert!(rel_frobenius_error(&Mat::eye(6), &utu) < 1e-9);
        let vvt = matmul(&r.vt, &r.vt.transpose());
        assert!(rel_frobenius_error(&Mat::eye(6), &vvt) < 1e-9);
    }

    #[test]
    fn power_iterations_help_flat_spectra() {
        let n = 64;
        let a = matrix_with_spectrum(n, Spectrum::Polynomial { power: 0.5 }, 7);
        let err = |q: usize| {
            let s = DigitalSketcher::new(24, n, 100 + q as u64);
            let r = randsvd(
                &s,
                &a,
                RandSvdOpts { rank: 8, oversample: 8, power_iters: q },
            );
            let rec = reconstruct(&r);
            // Compare against the optimal rank-8 truncation.
            let best = linalg::truncated(&a, 8);
            rel_frobenius_error(&best, &rec)
        };
        let e0 = err(0);
        let e3 = err(3);
        assert!(e3 < e0, "power iters did not help: {e0} -> {e3}");
    }

    #[test]
    fn near_optimal_vs_eckart_young() {
        let n = 56;
        let a = matrix_with_spectrum(n, Spectrum::Exponential { decay: 0.8 }, 9);
        let k = 8;
        let best_err = rel_frobenius_error(&a, &linalg::truncated(&a, k));
        let s = DigitalSketcher::new(32, n, 10);
        let r = randsvd(&s, &a, RandSvdOpts { rank: k, oversample: 12, power_iters: 2 });
        let rand_err = rel_frobenius_error(&a, &reconstruct(&r));
        assert!(
            rand_err < 1.3 * best_err + 1e-9,
            "randsvd {rand_err} vs optimal {best_err}"
        );
    }

    #[test]
    #[should_panic(expected = "rank+oversample")]
    fn rejects_undersized_sketcher() {
        let a = low_rank(32, 4, 11);
        let s = DigitalSketcher::new(8, 32, 12);
        randsvd(&s, &a, RandSvdOpts { rank: 8, oversample: 8, power_iters: 0 });
    }
}
