//! Nyström approximation of PSD matrices — an "extension" RandNLA method
//! beyond the paper's four demos (its conclusion invites exactly this kind
//! of pipeline: OPU randomization + compressed-domain algebra).
//!
//! A ~= (A G^T) (G A G^T)^+ (G A): two sketches, one m x m pseudo-inverse.

use crate::linalg::{self, matmul, Mat};
use crate::randnla::backend::Sketcher;

/// Nyström PSD approximation with spectral-cutoff pseudo-inverse.
pub fn nystrom(sketcher: &dyn Sketcher, a: &Mat, rcond: f64) -> Mat {
    assert!(a.is_square(), "nystrom needs PSD (square) input");
    assert_eq!(a.rows, sketcher.n());
    let ga = sketcher.project(a); // (m x n) = G A
    let agt = ga.transpose(); // A G^T for symmetric A
    let core = sketcher.project(&agt); // G A G^T (m x m)
    let core_pinv = pinv(&core.symmetrized(), rcond);
    matmul(&matmul(&agt, &core_pinv), &ga)
}

/// Moore-Penrose pseudo-inverse via the exact SVD with cutoff
/// `rcond * sigma_max`.
pub fn pinv(a: &Mat, rcond: f64) -> Mat {
    let linalg::Svd { u, s, vt } = linalg::svd(a);
    let cutoff = s.first().copied().unwrap_or(0.0) * rcond;
    let mut vs = vt.transpose();
    for i in 0..vs.rows {
        for (j, sv) in s.iter().enumerate() {
            let inv = if *sv > cutoff && *sv > 0.0 { 1.0 / sv } else { 0.0 };
            *vs.at_mut(i, j) *= inv;
        }
    }
    linalg::matmul_nt(&vs, &u)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::rel_frobenius_error;
    use crate::randnla::backend::DigitalSketcher;
    use crate::workload::psd_matrix;

    #[test]
    fn pinv_of_invertible_is_inverse() {
        let a = Mat::from_rows(&[vec![2.0, 0.0], vec![0.0, 4.0]]);
        let p = pinv(&a, 1e-12);
        let prod = matmul(&a, &p);
        assert!(rel_frobenius_error(&Mat::eye(2), &prod) < 1e-10);
    }

    #[test]
    fn pinv_handles_rank_deficiency() {
        let a = Mat::from_rows(&[vec![1.0, 0.0], vec![0.0, 0.0]]);
        let p = pinv(&a, 1e-10);
        // A A^+ A = A.
        let back = matmul(&matmul(&a, &p), &a);
        assert!(rel_frobenius_error(&a, &back) < 1e-10);
    }

    #[test]
    fn nystrom_reconstructs_low_rank_psd() {
        // PSD with inner dim 8 has rank <= 8; m = 24 captures it.
        let a = psd_matrix(48, 8, 1);
        let s = DigitalSketcher::new(24, 48, 2);
        let approx = nystrom(&s, &a, 1e-8);
        let rel = rel_frobenius_error(&a, &approx);
        assert!(rel < 0.05, "nystrom error {rel}");
    }

    #[test]
    fn nystrom_improves_with_m() {
        let a = psd_matrix(64, 32, 3);
        let err = |m: usize, seed| {
            let s = DigitalSketcher::new(m, 64, seed);
            rel_frobenius_error(&a, &nystrom(&s, &a, 1e-8))
        };
        let e_small: f64 = (0..5).map(|t| err(12, 10 + t)).sum::<f64>() / 5.0;
        let e_big: f64 = (0..5).map(|t| err(48, 20 + t)).sum::<f64>() / 5.0;
        assert!(e_big < e_small, "{e_small} -> {e_big}");
    }
}
