//! Hutchinson trace estimation — paper §II-B, eq. (4).
//!
//! `Tr(A) ~= Tr(G A G^T) / m`. Unbiased; Var = (2/m) ||A||_F^2 for
//! Gaussian G (up to the symmetric part), so the estimator sharpens as
//! 1/sqrt(m) — Fig. 1's trace panel sweeps exactly that.

use crate::linalg::Mat;
use crate::randnla::backend::Sketcher;
use crate::randnla::sketch::symmetric_sketch;

/// Estimate Tr(A) from one symmetric sketch.
pub fn hutchinson(sketcher: &dyn Sketcher, a: &Mat) -> f64 {
    symmetric_sketch(sketcher, a).trace()
}

/// Exact trace (baseline).
pub fn exact_trace(a: &Mat) -> f64 {
    a.trace()
}

/// Multi-probe variant: average `probes` independent digital estimates
/// sharing one sketcher family (variance-reduction ablation).
pub fn hutchinson_avg(
    mk_sketcher: impl Fn(u64) -> Box<dyn Sketcher>,
    a: &Mat,
    probes: usize,
) -> f64 {
    assert!(probes > 0);
    (0..probes)
        .map(|p| hutchinson(mk_sketcher(p as u64).as_ref(), a))
        .sum::<f64>()
        / probes as f64
}

/// Theoretical relative std of the estimator on a PSD matrix:
/// sqrt(2 ||A||_F^2 / m) / Tr(A).
pub fn predicted_rel_std(a: &Mat, m: usize) -> f64 {
    let fro2: f64 = a.data.iter().map(|v| v * v).sum();
    (2.0 * fro2 / m as f64).sqrt() / a.trace().abs().max(1e-300)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::randnla::backend::DigitalSketcher;
    use crate::workload::psd_matrix;

    #[test]
    fn unbiased() {
        let a = psd_matrix(48, 96, 1);
        let truth = exact_trace(&a);
        let mut acc = 0.0;
        let trials = 400;
        for t in 0..trials {
            let s = DigitalSketcher::new(16, 48, 2000 + t);
            acc += hutchinson(&s, &a);
        }
        let mean = acc / trials as f64;
        let rel = (mean - truth).abs() / truth;
        assert!(rel < 0.03, "bias {rel}");
    }

    #[test]
    fn error_shrinks_with_m() {
        let a = psd_matrix(64, 128, 2);
        let truth = exact_trace(&a);
        let spread = |m: usize| {
            let mut sq = 0.0;
            let trials = 60;
            for t in 0..trials {
                let s = DigitalSketcher::new(m, 64, 777 + t);
                let e = hutchinson(&s, &a) - truth;
                sq += e * e;
            }
            (sq / trials as f64).sqrt() / truth
        };
        let s8 = spread(8);
        let s64 = spread(64);
        assert!(s64 < s8, "{s8} -> {s64}");
        // 8x more rows -> ~sqrt(8) ~ 2.8x tighter.
        assert!(s8 / s64 > 1.6, "ratio {}", s8 / s64);
    }

    #[test]
    fn matches_predicted_variance_scale() {
        let a = psd_matrix(32, 64, 3);
        let m = 24;
        let truth = exact_trace(&a);
        let mut sq = 0.0;
        let trials = 200;
        for t in 0..trials {
            let s = DigitalSketcher::new(m, 32, 31 + t);
            let e = hutchinson(&s, &a) - truth;
            sq += e * e;
        }
        let emp = (sq / trials as f64).sqrt() / truth;
        let pred = predicted_rel_std(&a, m);
        // Within a factor ~2 of the Gaussian-theory prediction.
        assert!(emp / pred < 2.0 && emp / pred > 0.4, "emp {emp} pred {pred}");
    }

    #[test]
    fn averaging_probes_helps() {
        let a = psd_matrix(40, 80, 4);
        let truth = exact_trace(&a);
        let single_errs: f64 = (0..30)
            .map(|t| {
                let s = DigitalSketcher::new(8, 40, 900 + t);
                (hutchinson(&s, &a) - truth).abs()
            })
            .sum::<f64>()
            / 30.0;
        let avg_errs: f64 = (0..30)
            .map(|t| {
                let est = hutchinson_avg(
                    |p| Box::new(DigitalSketcher::new(8, 40, 5000 + 37 * t + p)),
                    &a,
                    8,
                );
                (est - truth).abs()
            })
            .sum::<f64>()
            / 30.0;
        assert!(avg_errs < single_errs, "{avg_errs} !< {single_errs}");
    }
}
