//! RandNLA algorithms over pluggable sketching backends (paper §II).
//!
//! Every algorithm is written against the [`backend::Sketcher`] seam so the
//! randomization step can run on the simulated OPU, the host CPU, or the
//! AOT-compiled PJRT path — the comparison that *is* the paper.

pub mod adaptive;
pub mod backend;
pub mod features;
pub mod hutchpp;
pub mod lstsq;
pub mod matmul;
pub mod nystrom;
pub mod randsvd;
pub mod sketch;
pub mod streaming;
pub mod structured;
pub mod trace;
pub mod triangles;

pub use adaptive::{
    adaptive_range, adaptive_range_digital, rank_for_tol, IncrementalRange, RangeFindResult,
    RangeFinderOpts,
};
pub use backend::{CounterSketcher, DigitalSketcher, PjrtSketcher, Sketcher};
pub use features::{gram_from_features, RffMap};
pub use hutchpp::{hutchpp, hutchpp_digital, split_budget, HutchPPSplit};
pub use lstsq::{
    exact_lstsq, sketch_precond_lstsq, sketched_lstsq, LsqrOpts, PrecondLstsq,
};
pub use matmul::{approx_matmul_tn, exact_matmul_tn};
pub use nystrom::nystrom;
pub use randsvd::{randsvd, RandSvd, RandSvdOpts};
pub use sketch::{symmetric_sketch, OpuSketcher};
pub use streaming::{
    fold_partials, one_pass_randsvd_digital, solve_corange, ChunkSketch, FrequentDirections,
    OnePassSvd, RowBlockSketcher,
};
pub use structured::{SparseSignSketcher, SrhtSketcher};
pub use trace::{exact_trace, hutchinson};
pub use triangles::{estimate_triangles, estimate_triangles_dense};
