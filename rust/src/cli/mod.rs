//! Minimal declarative CLI parser (no clap in the offline image).
//!
//! Supports `photon <subcommand> [--flag value] [--switch]` with typed
//! accessors and automatic usage text.

use std::collections::HashMap;

/// Parsed arguments: positionals + `--key value` options + `--switch`es.
#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    options: HashMap<String, String>,
    switches: Vec<String>,
}

impl Args {
    /// Parse an argv slice (excluding the program / subcommand names).
    /// Flags of known switches take no value; everything else `--k v`.
    pub fn parse(argv: &[String], switch_names: &[&str]) -> Result<Self, String> {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let tok = &argv[i];
            if let Some(name) = tok.strip_prefix("--") {
                if switch_names.contains(&name) {
                    out.switches.push(name.to_string());
                    i += 1;
                } else {
                    let val = argv
                        .get(i + 1)
                        .ok_or_else(|| format!("--{name} needs a value"))?;
                    if val.starts_with("--") {
                        return Err(format!("--{name} needs a value, got {val}"));
                    }
                    out.options.insert(name.to_string(), val.clone());
                    i += 2;
                }
            } else {
                out.positional.push(tok.clone());
                i += 1;
            }
        }
        Ok(out)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key} expects an integer, got {v}")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key} expects a number, got {v}")),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key} expects an integer, got {v}")),
        }
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }

    /// Comma-separated list of integers.
    pub fn get_usize_list(&self, key: &str, default: &[usize]) -> Result<Vec<usize>, String> {
        match self.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|s| {
                    s.trim()
                        .parse()
                        .map_err(|_| format!("--{key} expects comma-separated ints, got {v}"))
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(parts: &[&str]) -> Vec<String> {
        parts.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_options_and_switches() {
        let a = Args::parse(&sv(&["--n", "128", "--verbose", "pos1"]), &["verbose"]).unwrap();
        assert_eq!(a.get("n"), Some("128"));
        assert!(a.has("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn typed_accessors() {
        let a = Args::parse(&sv(&["--n", "42", "--rho", "0.5", "--list", "1,2,3"]), &[]).unwrap();
        assert_eq!(a.get_usize("n", 0).unwrap(), 42);
        assert_eq!(a.get_f64("rho", 0.0).unwrap(), 0.5);
        assert_eq!(a.get_usize_list("list", &[]).unwrap(), vec![1, 2, 3]);
        assert_eq!(a.get_usize("missing", 7).unwrap(), 7);
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(&sv(&["--n"]), &[]).is_err());
        assert!(Args::parse(&sv(&["--n", "--m"]), &[]).is_err());
    }

    #[test]
    fn bad_type_is_error() {
        let a = Args::parse(&sv(&["--n", "abc"]), &[]).unwrap();
        assert!(a.get_usize("n", 0).is_err());
    }
}
