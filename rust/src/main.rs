//! `photon` — CLI driver for the photonic-RandNLA reproduction.
//!
//! Subcommands:
//!   fig1    regenerate Fig. 1 (quality: matmul/trace/triangles/randsvd)
//!   fig2    regenerate Fig. 2 (projection time vs dimension)
//!   claims  check the §I/§III scalar claims against the models
//!   serve   run the coordinator over a synthetic job trace (E2E demo),
//!           or front it over TCP with --listen/--tenants
//!   worker  join a coordinator's front door as a map worker for the
//!           scale-out plane (partitioned stream ingest)
//!   remote  drive a remote coordinator over the wire protocol
//!   info    artifact + device inventory

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};

use photonic_randnla::cli::Args;
use photonic_randnla::coordinator::{
    BatchConfig, Coordinator, CoordinatorConfig, HostSketch, JobSpec, LsqrOpts, MetricsServer,
    OperandId, OperandRef, Payload, Policy, PoolConfig, Precision, PrecisionPolicy, StreamError,
    StreamId, StreamOpts, SubmitOptions, TenantRegistry, Ticket, TraceEstimator,
};
use photonic_randnla::graph::generators::erdos_renyi;
use photonic_randnla::linalg::{matvec, Mat};
use photonic_randnla::net::{WireClient, WireServer, WorkerConfig, WorkerNode};
use photonic_randnla::opu::NoiseModel;
use photonic_randnla::perfmodel::SketchKind;
use photonic_randnla::reports::{claims, fig1, fig2, print_rows, Row};
use photonic_randnla::rng::Xoshiro256;
use photonic_randnla::runtime::PjrtEngine;
use photonic_randnla::workload::traces::{self, JobKind, TraceConfig};
use photonic_randnla::workload::{correlated_pair, psd_matrix};

const USAGE: &str = "photon <fig1|fig2|claims|serve|worker|remote|info> [options]

  fig1   [--panel matmul|trace|triangles|randsvd|all] [--n 256]
         [--trials 3] [--noise ideal|realistic|harsh] [--seed 7]
  fig2   [--no-measure] [--reps 5] [--artifacts DIR]
  claims
  serve  [--jobs 64] [--policy auto|opu|pjrt|host] [--workers 4]
         [--sketch dense|srht|sparse|auto] (host digital operator)
         [--opu-replicas 1] [--pjrt-replicas 1] [--host-workers 1]
         [--queue-cap 1024] (bounded admission queue; Busy beyond it)
         [--store-mb 1024] (operand-store quota; 0 = unbounded)
         [--cache-mb 0] (content-addressed sketch-cache budget;
           0 = cache off — every submission takes the compute path)
         [--adaptive-tol 0.05] (rel. error target of adaptive-svd jobs)
         [--precision requested|f64|f32|bf16|auto] (arithmetic tier:
           requested honors each job, f64/f32/bf16 force one tier,
           auto lets accuracy contracts buy cheaper tiers)
         [--stream-chunk-rows 256] (streaming-ingest chunk size)
         [--artifacts DIR] [--compression 0.25] [--sizes 128,256,512]
         [--listen ADDR] [--tenants FILE] (network front door: serve
           the session API over framed TCP instead of the synthetic
           trace; FILE has one name:token:quota_mb:qos per line,
           quota_mb 0 = unbounded, qos interactive|batch;
           Ctrl-C drains in-flight jobs and syncs the event log)
         [--expect-workers N] (with --listen: wait for N map workers
           to join before announcing readiness; streams opened while
           workers are connected are partitioned across them)
         [--metrics-listen ADDR] (arm the telemetry plane and serve
           the Prometheus text exposition at GET /metrics on ADDR)
         [--trace-out FILE] (arm the telemetry plane and stream
           completed job spans to FILE as Chrome trace_event JSON;
           load it at chrome://tracing or ui.perfetto.dev)
  worker --connect HOST:PORT --token TOKEN
         [--policy host|auto] [--noise ideal|realistic|harsh]
           (join the coordinator as a map worker: ingest forwarded
           stream partitions and push mergeable FD/sketch summaries;
           Ctrl-C leaves the cluster)
  remote --connect HOST:PORT --token TOKEN
         [--op trace|projection|randsvd|nystrom] [--n 256] [--m 64]
         [--jobs 8] [--seed 7] [--report] (print the server's
           metrics report: global gauges + your own tenant lines)
         [--metrics] (print the server's Prometheus text exposition
           through the authed session — no scrape port needed)
  info   [--artifacts DIR]";

/// Set by the SIGINT handler; `serve --listen` polls it to begin a
/// graceful shutdown.
static CTRL_C: AtomicBool = AtomicBool::new(false);

extern "C" fn on_sigint(_sig: i32) {
    CTRL_C.store(true, Ordering::SeqCst);
}

#[cfg(unix)]
fn install_sigint() {
    extern "C" {
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    }
    // SIGINT = 2 everywhere POSIX; std exposes no signal API.
    unsafe {
        signal(2, on_sigint);
    }
}

#[cfg(not(unix))]
fn install_sigint() {}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let result = match argv.first().map(|s| s.as_str()) {
        Some("fig1") => cmd_fig1(&argv[1..]),
        Some("fig2") => cmd_fig2(&argv[1..]),
        Some("claims") => cmd_claims(),
        Some("serve") => cmd_serve(&argv[1..]),
        Some("worker") => cmd_worker(&argv[1..]),
        Some("remote") => cmd_remote(&argv[1..]),
        Some("info") => cmd_info(&argv[1..]),
        _ => {
            eprintln!("{USAGE}");
            Err("missing or unknown subcommand".to_string())
        }
    };
    let code = match result {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    };
    std::process::exit(code);
}

fn noise_from(name: &str) -> Result<NoiseModel, String> {
    match name {
        "ideal" => Ok(NoiseModel::ideal()),
        "realistic" => Ok(NoiseModel::realistic()),
        "harsh" => Ok(NoiseModel::harsh()),
        other => Err(format!("unknown noise model {other}")),
    }
}

fn cmd_fig1(argv: &[String]) -> Result<(), String> {
    let args = Args::parse(argv, &[])?;
    let cfg = fig1::Fig1Config {
        n: args.get_usize("n", 256)?,
        trials: args.get_usize("trials", 3)?,
        seed: args.get_u64("seed", 7)?,
        noise: noise_from(&args.get_or("noise", "realistic"))?,
        ..Default::default()
    };
    let panel = args.get_or("panel", "all");
    let rows: Vec<Row> = match panel.as_str() {
        "matmul" => fig1::matmul_panel(&cfg),
        "trace" => fig1::trace_panel(&cfg),
        "triangles" => fig1::triangles_panel(&cfg),
        "randsvd" => fig1::randsvd_panel(&cfg),
        "all" => fig1::all_panels(&cfg),
        other => return Err(format!("unknown panel {other}")),
    };
    print_rows(&format!("Fig. 1 ({panel}) n={} trials={}", cfg.n, cfg.trials), &rows);
    match fig1::optical_matches_numerical(&rows, 0.9) {
        Ok(()) => println!("\nheadline check: optical == numerical within tolerance: OK"),
        Err(e) => println!("\nheadline check FAILED: {e}"),
    }
    Ok(())
}

fn cmd_fig2(argv: &[String]) -> Result<(), String> {
    let args = Args::parse(argv, &["no-measure"])?;
    let cfg = fig2::Fig2Config {
        reps: args.get_usize("reps", 5)?,
        ..Default::default()
    };
    let mut rows = fig2::model_rows(&cfg);
    if !args.has("no-measure") {
        let dir = PathBuf::from(args.get_or("artifacts", "artifacts"));
        match PjrtEngine::start(dir) {
            Ok(engine) => match fig2::measured_pjrt_rows(&engine.handle(), &cfg) {
                Ok(mut measured) => rows.append(&mut measured),
                Err(e) => eprintln!("(measured PJRT points skipped: {e})"),
            },
            Err(e) => eprintln!("(PJRT engine unavailable, model-only: {e})"),
        }
    }
    print_rows("Fig. 2 - projection time vs dimension (ms)", &rows);
    let h = fig2::headline();
    println!(
        "\ncrossover n ~ {} (paper ~1.2e4) | GPU OOM n ~ {} (paper ~7e4) | \
         OPU @1e6 = {:.2} ms (paper ~1.2 ms)",
        h.crossover_dim, h.gpu_oom_dim, h.opu_ms_at_1m
    );
    Ok(())
}

fn cmd_claims() -> Result<(), String> {
    let cs = claims::all_claims();
    claims::print_claims(&cs);
    if cs.iter().all(|c| c.holds()) {
        println!("\nall claims reproduced within tolerance: OK");
        Ok(())
    } else {
        Err("some claims failed".into())
    }
}

fn cmd_serve(argv: &[String]) -> Result<(), String> {
    let args = Args::parse(argv, &[])?;
    let policy = match args.get_or("policy", "auto").as_str() {
        "auto" => Policy::Auto,
        "opu" => Policy::ForceOpu,
        "pjrt" => Policy::ForcePjrt,
        "host" => Policy::ForceHost,
        other => return Err(format!("unknown policy {other}")),
    };
    // Digital operator for the host arm: dense keeps the seed behaviour,
    // srht/sparse force a structured fast sketch, auto lets the router
    // price all three per signature and pick the cheapest.
    let host_sketch = match args.get_or("sketch", "dense").as_str() {
        "dense" => HostSketch::Fixed(SketchKind::Dense),
        "srht" => HostSketch::Fixed(SketchKind::Srht),
        "sparse" => HostSketch::Fixed(SketchKind::Sparse),
        "auto" => HostSketch::Auto,
        other => return Err(format!("unknown sketch operator {other}")),
    };
    let artifacts = args.get("artifacts").map(PathBuf::from).or_else(|| {
        std::path::Path::new("artifacts/manifest.json")
            .exists()
            .then(|| PathBuf::from("artifacts"))
    });
    let trace_cfg = TraceConfig {
        jobs: args.get_usize("jobs", 64)?,
        compression: args.get_f64("compression", 0.25)?,
        sizes: args.get_usize_list("sizes", &[128, 256, 512])?,
        seed: args.get_u64("seed", 0)?,
        ..Default::default()
    };
    let pool = PoolConfig {
        opu_replicas: args.get_usize("opu-replicas", 1)?,
        pjrt_replicas: args.get_usize("pjrt-replicas", 1)?,
        host_workers: args.get_usize("host-workers", 1)?,
        ..Default::default()
    };
    let store_mb = args.get_usize("store-mb", 1024)?;
    let cache_mb = args.get_usize("cache-mb", 0)?;
    let adaptive_tol = args.get_f64("adaptive-tol", 0.05)?;
    if adaptive_tol <= 0.0 || adaptive_tol >= 1.0 {
        return Err(format!("--adaptive-tol must lie in (0, 1), got {adaptive_tol}"));
    }
    let stream_chunk_rows = args.get_usize("stream-chunk-rows", 256)?;
    if stream_chunk_rows == 0 {
        return Err("--stream-chunk-rows must be >= 1".into());
    }
    // Arithmetic-tier policy. The trace driver submits with default
    // options (requested tier f64), so `requested` keeps the seeded
    // behaviour bit for bit; a named tier is a server-wide override;
    // `auto` lets the adaptive-svd jobs' --adaptive-tol contract buy a
    // cheaper tier. Operator draws are tier-independent either way, so
    // seeded draw counts never change with this flag.
    let precision = match args.get_or("precision", "requested").as_str() {
        "requested" => PrecisionPolicy::Requested,
        "auto" => PrecisionPolicy::Auto,
        tier => match Precision::parse(tier) {
            Some(p) => PrecisionPolicy::Fixed(p),
            None => return Err(format!("unknown precision tier {tier}")),
        },
    };
    // The telemetry plane arms whenever either output is requested;
    // without both flags the serving plane is bit-for-bit the
    // pre-telemetry one (no stage events, no span assembly).
    let metrics_listen = args.get("metrics-listen");
    let trace_out = args.get("trace-out").map(PathBuf::from);
    let telemetry = metrics_listen.is_some() || trace_out.is_some();
    let coord = Coordinator::start(CoordinatorConfig {
        workers: args.get_usize("workers", 4)?,
        policy,
        host_sketch,
        batch: BatchConfig::default(),
        pool,
        artifacts_dir: artifacts,
        queue_cap: args.get_usize("queue-cap", 1024)?,
        store_quota: if store_mb == 0 { usize::MAX } else { store_mb * 1024 * 1024 },
        stream_chunk_rows,
        precision,
        cache_quota: cache_mb * 1024 * 1024,
        telemetry,
        trace_out,
    })
    .map_err(|e| e.to_string())?;

    // Scrape endpoint: a std-only HTTP/1.1 responder rendering the
    // registry on every GET /metrics. Held until the engine drains so
    // the last scrape still answers during shutdown.
    let _metrics_srv = match (&metrics_listen, coord.telemetry()) {
        (Some(addr), Some(registry)) => {
            let registry = std::sync::Arc::clone(registry);
            let srv =
                MetricsServer::start(addr, std::sync::Arc::new(move || registry.render()))
                    .map_err(|e| e.to_string())?;
            println!("telemetry: scrape endpoint at http://{}/metrics", srv.addr());
            Some(srv)
        }
        _ => None,
    };

    // Network front door: hand the engine to the TCP serving plane and
    // run until SIGINT, then drain gracefully (no synthetic trace).
    if let Some(listen) = args.get("listen") {
        let tenants_path = args.get("tenants").ok_or_else(|| {
            "--listen requires --tenants FILE (one name:token:quota_mb:qos per line)"
                .to_string()
        })?;
        let tenants = TenantRegistry::load(tenants_path)?;
        let provisioned = tenants.len();
        let expect_workers = args.get_usize("expect-workers", 0)?;
        let server = WireServer::start(coord, listen, tenants).map_err(|e| e.to_string())?;
        println!(
            "front door listening on {} ({provisioned} tenant(s) provisioned; \
             policy {policy:?}, precision {precision:?})",
            server.addr()
        );
        println!("Ctrl-C to shut down: drains in-flight jobs, then syncs the event log");
        install_sigint();
        if expect_workers > 0 {
            println!("waiting for {expect_workers} map worker(s) to join...");
            while server.coordinator().cluster().worker_count() < expect_workers
                && !CTRL_C.load(Ordering::SeqCst)
            {
                std::thread::sleep(std::time::Duration::from_millis(50));
            }
            let names = server.coordinator().cluster().worker_names();
            println!(
                "scale-out plane ready: {} worker(s) joined ({})",
                names.len(),
                names.join(", ")
            );
        }
        while !CTRL_C.load(Ordering::SeqCst) {
            std::thread::sleep(std::time::Duration::from_millis(50));
        }
        println!("\nshutting down: draining in-flight jobs...");
        let report = server.coordinator().report();
        server.shutdown();
        println!("{report}");
        return Ok(());
    }

    let trace = traces::generate(&trace_cfg);
    println!(
        "serving {} jobs (policy {policy:?}, host sketch {host_sketch:?}, \
         precision {precision:?})...",
        trace.len()
    );
    // Session-API driver: every operand is uploaded once and submitted
    // by handle — the payload is never re-shipped per job. Finished jobs
    // are reaped as we go so freed operands bound the resident store to
    // in-flight work, whatever --jobs is.
    let t0 = std::time::Instant::now();
    let mut in_flight: InFlight = std::collections::VecDeque::new();
    let mut ok = 0usize;
    let mut peak_store = 0usize;
    for spec in &trace {
        reap_finished(&coord, &mut in_flight, &mut ok);
        let pair = submit_trace_job(&coord, spec, adaptive_tol, &mut in_flight, &mut ok)?;
        in_flight.push_back(pair);
        peak_store = peak_store.max(coord.store().bytes());
    }
    while reap_front(&coord, &mut in_flight, &mut ok) {}
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "completed {ok}/{} jobs in {wall:.2}s ({:.1} jobs/s)",
        trace.len(),
        ok as f64 / wall
    );
    println!(
        "operand store: peak {:.1} MiB across {} jobs, {} B resident after free",
        peak_store as f64 / (1024.0 * 1024.0),
        trace.len(),
        coord.store().bytes()
    );
    println!("{}", coord.report());
    coord.shutdown();
    Ok(())
}

fn cmd_worker(argv: &[String]) -> Result<(), String> {
    let args = Args::parse(argv, &[])?;
    let addr = args.get("connect").ok_or("worker requires --connect HOST:PORT")?;
    let token = args.get("token").ok_or("worker requires --token TOKEN")?;
    let mut cfg = WorkerConfig::default();
    cfg.policy = match args.get_or("policy", "host").as_str() {
        "host" => Policy::ForceHost,
        "auto" => Policy::Auto,
        other => return Err(format!("unknown worker policy {other}")),
    };
    cfg.batch.noise = noise_from(&args.get_or("noise", "ideal"))?;
    let node = WorkerNode::connect(&addr, &token, cfg).map_err(|e| e.to_string())?;
    println!(
        "worker {} joined coordinator {} (Ctrl-C to leave the cluster)",
        node.worker_id(),
        node.addr()
    );
    install_sigint();
    while !CTRL_C.load(Ordering::SeqCst) {
        std::thread::sleep(std::time::Duration::from_millis(50));
    }
    println!("\nleaving the cluster...");
    let metrics = node.metrics();
    node.shutdown();
    println!(
        "worker done: {} chunk(s) ingested, {} B resident",
        metrics.stream_chunks.load(Ordering::Relaxed),
        metrics.stream_resident_bytes.load(Ordering::Relaxed)
    );
    Ok(())
}

/// Jobs submitted but not yet waited on, with the operand handles and
/// streams they own.
type InFlight = std::collections::VecDeque<(Ticket, Vec<OperandId>, Vec<StreamId>)>;

/// Block on the oldest in-flight job and free its operands and streams;
/// false when nothing is in flight.
fn reap_front(coord: &Coordinator, in_flight: &mut InFlight, ok: &mut usize) -> bool {
    match in_flight.pop_front() {
        Some((t, handles, streams)) => {
            if t.wait().is_ok() {
                *ok += 1;
            }
            for h in handles {
                coord.free_operand(h);
            }
            for s in streams {
                coord.free_stream(s);
            }
            true
        }
        None => false,
    }
}

/// Non-blocking reap: retire every already-finished job at the front of
/// the in-flight queue, freeing its operands and streams.
fn reap_finished(coord: &Coordinator, in_flight: &mut InFlight, ok: &mut usize) {
    loop {
        let done = match in_flight.front() {
            Some((t, ..)) => t.try_wait(),
            None => None,
        };
        match done {
            Some(res) => {
                let (_t, handles, streams) =
                    in_flight.pop_front().expect("front just observed");
                if res.is_ok() {
                    *ok += 1;
                }
                for h in handles {
                    coord.free_operand(h);
                }
                for s in streams {
                    coord.free_stream(s);
                }
            }
            None => break,
        }
    }
}

/// Build one trace job's operands, upload them, and submit the
/// handle-based spec. Both backpressure signals are absorbed: a full
/// queue by blocking on its space condvar (`submit_spec_wait`), an
/// over-quota store by retiring the oldest in-flight jobs (blocking)
/// until the upload is admitted.
fn submit_trace_job(
    coord: &Coordinator,
    spec: &traces::JobSpec,
    adaptive_tol: f64,
    in_flight: &mut InFlight,
    ok: &mut usize,
) -> Result<(Ticket, Vec<OperandId>, Vec<StreamId>), String> {
    // Streaming kinds never upload the operand: rows are chunk-ingested
    // through the streaming plane and the job runs one-pass.
    if matches!(spec.kind, JobKind::StreamIngest | JobKind::StreamSvd) {
        return submit_stream_job(coord, spec, in_flight, ok);
    }
    let mut handles = Vec::new();
    let mut upload = |m: Mat| -> Result<OperandRef, String> {
        let arc = std::sync::Arc::new(m);
        loop {
            match coord.store().insert(arc.clone()) {
                Ok(id) => {
                    handles.push(id);
                    return Ok(OperandRef::Handle(id));
                }
                // Store full (typed backpressure): retire the oldest
                // in-flight job to free its operands and retry. With
                // nothing left to retire, the operand genuinely
                // exceeds the quota.
                Err(e) => {
                    if !reap_front(coord, in_flight, ok) {
                        return Err(e.to_string());
                    }
                }
            }
        }
    };
    let job = match spec.kind {
        JobKind::SketchMatmul => {
            let (a, b) = correlated_pair(spec.n, 0.5, spec.seed);
            JobSpec::ApproxMatmul { a: upload(a)?, b: upload(b)?, m: spec.m }
        }
        JobKind::TraceEstimate => JobSpec::Trace {
            a: upload(psd_matrix(spec.n, spec.n / 2, spec.seed))?,
            m: spec.m,
            estimator: TraceEstimator::Hutchinson,
        },
        // Same operand family and column budget as TraceEstimate — the
        // estimator knob is the only difference, which is exactly the
        // comparison benches/adaptive.rs grades.
        JobKind::HutchPP => JobSpec::Trace {
            a: upload(psd_matrix(spec.n, spec.n / 2, spec.seed))?,
            m: spec.m.max(3),
            estimator: TraceEstimator::HutchPP,
        },
        JobKind::TriangleCount => {
            let g = erdos_renyi(spec.n, 0.05, spec.seed);
            JobSpec::Triangles { adjacency: upload(g.adjacency())?, m: spec.m }
        }
        JobKind::RandSvd => JobSpec::RandSvd {
            a: upload(psd_matrix(spec.n, spec.n, spec.seed))?,
            rank: spec.m.min(spec.n / 4).max(4),
            oversample: 8,
            power_iters: 1,
            publish_q: false,
            tol: None,
        },
        // Accuracy-first SVD: the rank cap is generous and the
        // incremental rangefinder decides how much of it to spend.
        JobKind::AdaptiveSvd => JobSpec::RandSvd {
            a: upload(psd_matrix(spec.n, spec.n / 8, spec.seed))?,
            rank: spec.m.min(spec.n / 2).max(8),
            oversample: 8,
            power_iters: 0,
            publish_q: false,
            tol: Some(adaptive_tol),
        },
        JobKind::LstsqSolve | JobKind::LstsqPrecond => {
            let mut rng = Xoshiro256::new(spec.seed);
            let cols = (spec.n / 16).clamp(4, spec.m.max(4));
            let a = Mat::gaussian(spec.n, cols, 1.0, &mut rng);
            let x: Vec<f64> = (0..cols).map(|_| rng.next_normal()).collect();
            let mut b = matvec(&a, &x);
            for v in b.iter_mut() {
                *v += 0.1 * rng.next_normal();
            }
            let refine = match spec.kind {
                JobKind::LstsqPrecond => Some(LsqrOpts::default()),
                _ => None,
            };
            JobSpec::Lstsq { a: upload(a)?, b, m: spec.m.max(cols), refine }
        }
        JobKind::NystromApprox => JobSpec::Nystrom {
            a: upload(psd_matrix(spec.n, spec.n / 4, spec.seed))?,
            m: spec.m,
            rcond: 1e-8,
        },
        JobKind::StreamIngest | JobKind::StreamSvd => unreachable!("handled above"),
    };
    // Blocking admission: the queue's space condvar replaces the old
    // 1 ms Busy sleep-poll loop.
    coord
        .submit_spec_wait(job, SubmitOptions::default())
        .map(|t| (t, handles, Vec::new()))
        .map_err(|e| e.to_string())
}

/// Streaming trace jobs: chunk-ingest the operand (the driver generates
/// it whole as a synthetic client, but the coordinator only ever holds
/// one chunk buffer plus the bounded summaries), seal, and run the
/// one-pass consumer. An over-quota `begin` retires the oldest in-flight
/// jobs until the stream's bounded footprint is admitted.
fn submit_stream_job(
    coord: &Coordinator,
    spec: &traces::JobSpec,
    in_flight: &mut InFlight,
    ok: &mut usize,
) -> Result<(Ticket, Vec<OperandId>, Vec<StreamId>), String> {
    // Derived sizes, computed once: the StreamOpts and the JobSpec below
    // must agree (trace's m == sketch_m; randsvd's rank + oversample ==
    // range_cap) or the one-pass consumer fails its budget check. Every
    // budget clamps to the stream's row count so tiny --sizes values
    // still serve (range_cap > rows is a BadOpts refusal).
    let trace_m = spec.m.max(4);
    let svd_rank = spec.m.min(spec.n / 4).max(4).min(spec.n);
    let svd_cap = (svd_rank + 8).min(spec.n);
    let svd_oversample = svd_cap - svd_rank;
    let (a, opts) = match spec.kind {
        // Ingest-heavy: a square operand consumed by the streaming
        // Hutchinson trace at the stream's sketch width.
        JobKind::StreamIngest => (
            psd_matrix(spec.n, spec.n / 2, spec.seed),
            StreamOpts {
                chunk_rows: None,
                sketch_m: trace_m,
                fd_rank: 16.min(spec.n),
                range_cap: 8.min(spec.n),
            },
        ),
        JobKind::StreamSvd => (
            psd_matrix(spec.n, spec.n / 8, spec.seed),
            StreamOpts {
                chunk_rows: None,
                sketch_m: 2 * svd_cap,
                fd_rank: svd_rank.max(8).min(spec.n.max(1)),
                range_cap: svd_cap,
            },
        ),
        _ => unreachable!("not a streaming kind"),
    };
    let sid = loop {
        match coord.begin_stream(a.rows, a.cols, opts) {
            Ok(id) => break id,
            // Store full: retire the oldest in-flight job and retry,
            // mirroring the upload path's quota-retire loop.
            Err(StreamError::OverQuota(_)) if reap_front(coord, in_flight, ok) => {}
            Err(e) => return Err(e.to_string()),
        }
    };
    let ingest = coord
        .append_stream(sid, &a)
        .and_then(|()| coord.seal_stream(sid));
    if let Err(e) = ingest {
        coord.free_stream(sid);
        return Err(e.to_string());
    }
    let job = match spec.kind {
        JobKind::StreamIngest => JobSpec::Trace {
            a: OperandRef::Stream(sid),
            m: trace_m,
            estimator: TraceEstimator::Hutchinson,
        },
        JobKind::StreamSvd => JobSpec::RandSvd {
            a: OperandRef::Stream(sid),
            rank: svd_rank,
            oversample: svd_oversample,
            power_iters: 0,
            publish_q: false,
            tol: None,
        },
        _ => unreachable!("not a streaming kind"),
    };
    match coord.submit_spec_wait(job, SubmitOptions::default()) {
        Ok(t) => Ok((t, Vec::new(), vec![sid])),
        Err(e) => {
            coord.free_stream(sid);
            Err(e.to_string())
        }
    }
}

/// Drive a remote coordinator over the wire protocol: authenticate,
/// upload one operand, submit `--jobs` handle-based jobs, wait for all
/// of them, and free the handle — the network twin of the `serve`
/// trace driver's session lifecycle.
fn cmd_remote(argv: &[String]) -> Result<(), String> {
    let args = Args::parse(argv, &["report", "metrics"])?;
    let addr = args
        .get("connect")
        .ok_or_else(|| "--connect HOST:PORT is required".to_string())?;
    let token = args.get("token").ok_or_else(|| "--token TOKEN is required".to_string())?;
    let n = args.get_usize("n", 256)?;
    let m = args.get_usize("m", 64)?;
    let jobs = args.get_usize("jobs", 8)?;
    let seed = args.get_u64("seed", 7)?;

    let client = WireClient::connect(addr, token).map_err(|e| e.to_string())?;
    let quota = match client.quota() {
        usize::MAX => "unbounded".to_string(),
        q => format!("{:.1} MiB", q as f64 / (1024.0 * 1024.0)),
    };
    println!(
        "connected to {addr} as tenant {} (qos {}, quota {quota})",
        client.tenant(),
        client.qos().label()
    );

    let id = client.upload(&psd_matrix(n, n / 2, seed)).map_err(|e| e.to_string())?;
    println!("uploaded {n}x{n} operand as {id}");
    let spec = match args.get_or("op", "trace").as_str() {
        "trace" => JobSpec::Trace {
            a: OperandRef::Handle(id),
            m,
            estimator: TraceEstimator::Hutchinson,
        },
        "projection" => JobSpec::Projection { data: OperandRef::Handle(id), m },
        "randsvd" => JobSpec::RandSvd {
            a: OperandRef::Handle(id),
            rank: m.min(n / 4).max(4),
            oversample: 8,
            power_iters: 1,
            publish_q: false,
            tol: None,
        },
        "nystrom" => JobSpec::Nystrom { a: OperandRef::Handle(id), m, rcond: 1e-8 },
        other => return Err(format!("unknown --op {other}")),
    };

    // Pipelined: all submissions are acked before the first wait, so
    // the server batches across them exactly as it would in-process.
    let t0 = std::time::Instant::now();
    let tickets = (0..jobs)
        .map(|_| client.submit(&spec, SubmitOptions::default()))
        .collect::<Result<Vec<_>, _>>()
        .map_err(|e| e.to_string())?;
    let mut ok = 0usize;
    for t in tickets {
        let r = t.wait().map_err(|e| e.to_string())?;
        let desc = match &r.payload {
            Payload::Scalar(v) => format!("scalar {v:.6}"),
            Payload::Matrix(mat) => format!("{}x{} matrix", mat.rows, mat.cols),
            Payload::Vector(v) => format!("vector[{}]", v.len()),
            Payload::Svd { s, .. } => format!("svd rank {}", s.len()),
        };
        println!(
            "  job {}: {} on {} ({} us) -> {desc}",
            r.id,
            r.kind,
            r.device.name(),
            r.latency_us
        );
        ok += 1;
    }
    let wall = t0.elapsed().as_secs_f64();
    println!("completed {ok}/{jobs} jobs in {wall:.2}s ({:.1} jobs/s)", ok as f64 / wall);
    client.free_operand(id).map_err(|e| e.to_string())?;
    if args.has("report") {
        println!("{}", client.report().map_err(|e| e.to_string())?);
    }
    if args.has("metrics") {
        println!("{}", client.metrics().map_err(|e| e.to_string())?);
    }
    Ok(())
}

fn cmd_info(argv: &[String]) -> Result<(), String> {
    let args = Args::parse(argv, &[])?;
    let dir = PathBuf::from(args.get_or("artifacts", "artifacts"));
    println!("photonic-randnla - artifact & device inventory");
    match PjrtEngine::start(dir.clone()) {
        Ok(engine) => {
            let h = engine.handle();
            let names = h.unit_names().map_err(|e| e.to_string())?;
            println!("artifacts dir: {dir:?} ({} units)", names.len());
            for n in names {
                println!("  {n}");
            }
            println!("proj_xla buckets: {:?}", h.buckets("proj_xla").unwrap_or_default());
        }
        Err(e) => println!("artifacts unavailable: {e}"),
    }
    let h = fig2::headline();
    println!(
        "models: crossover {} | oom {} | opu@1e6 {:.2} ms",
        h.crossover_dim, h.gpu_oom_dim, h.opu_ms_at_1m
    );
    Ok(())
}
