//! Fig. 1 — quality of OPU vs numerical randomization on the four RandNLA
//! tasks. Each panel sweeps compression (or rank) and reports the relative
//! error of the optical arm against the digital arm on identical inputs.

use std::sync::Arc;

use super::Row;
use crate::graph::generators::erdos_renyi;
use crate::graph::karate::karate_club;
use crate::linalg::{self, rel_frobenius_error, rel_scalar_error};
use crate::opu::{NoiseModel, OpuConfig, OpuDevice};
use crate::randnla::{
    approx_matmul_tn, estimate_triangles_dense, exact_matmul_tn, hutchinson, randsvd,
    DigitalSketcher, OpuSketcher, RandSvdOpts,
};
use crate::stats::Running;
use crate::workload::{correlated_pair, matrix_with_spectrum, psd_matrix, Spectrum};

/// Sweep parameters shared by the four panels.
#[derive(Clone, Debug)]
pub struct Fig1Config {
    pub n: usize,
    pub ratios: Vec<f64>,
    pub trials: usize,
    pub seed: u64,
    pub noise: NoiseModel,
}

impl Default for Fig1Config {
    fn default() -> Self {
        Self {
            n: 256,
            ratios: vec![0.0625, 0.125, 0.25, 0.5, 0.75, 1.0],
            trials: 3,
            seed: 7,
            noise: NoiseModel::realistic(),
        }
    }
}

impl Fig1Config {
    fn m_for(&self, ratio: f64) -> usize {
        ((self.n as f64 * ratio) as usize).max(8)
    }

    fn opu(&self, m: usize, trial: u64) -> OpuSketcher {
        let cfg = OpuConfig::new(self.seed ^ (trial << 17) ^ m as u64, m, self.n)
            .with_noise(self.noise.clone());
        OpuSketcher::new(Arc::new(OpuDevice::new(cfg)))
    }

    fn digital(&self, m: usize, trial: u64) -> DigitalSketcher {
        DigitalSketcher::new(m, self.n, self.seed ^ (trial << 17) ^ m as u64)
    }
}

fn summarize(
    panel: &'static str,
    x_label: &'static str,
    x: f64,
    arm: &str,
    errs: &[f64],
) -> Row {
    let mut r = Running::new();
    for &e in errs {
        r.push(e);
    }
    Row {
        panel,
        x_label,
        x,
        arm: arm.to_string(),
        y: r.mean(),
        ci95: r.ci95(),
        trials: errs.len(),
    }
}

/// Panel (a): approximate matrix multiplication.
pub fn matmul_panel(cfg: &Fig1Config) -> Vec<Row> {
    let (a, b) = correlated_pair(cfg.n, 0.5, cfg.seed);
    let want = exact_matmul_tn(&a, &b);
    let mut rows = Vec::new();
    for &ratio in &cfg.ratios {
        let m = cfg.m_for(ratio);
        for arm in ["digital", "opu"] {
            let errs: Vec<f64> = (0..cfg.trials as u64)
                .map(|t| {
                    let approx = match arm {
                        "digital" => approx_matmul_tn(&cfg.digital(m, t), &a, &b),
                        _ => approx_matmul_tn(&cfg.opu(m, t), &a, &b),
                    };
                    rel_frobenius_error(&want, &approx)
                })
                .collect();
            rows.push(summarize("fig1-matmul", "m/n", ratio, arm, &errs));
        }
    }
    rows
}

/// Panel (b): Hutchinson trace estimation on a PSD matrix.
pub fn trace_panel(cfg: &Fig1Config) -> Vec<Row> {
    let a = psd_matrix(cfg.n, cfg.n / 2, cfg.seed);
    let truth = a.trace();
    let mut rows = Vec::new();
    for &ratio in &cfg.ratios {
        let m = cfg.m_for(ratio);
        for arm in ["digital", "opu"] {
            let errs: Vec<f64> = (0..cfg.trials as u64)
                .map(|t| {
                    let est = match arm {
                        "digital" => hutchinson(&cfg.digital(m, t), &a),
                        _ => hutchinson(&cfg.opu(m, t), &a),
                    };
                    rel_scalar_error(truth, est)
                })
                .collect();
            rows.push(summarize("fig1-trace", "m/n", ratio, arm, &errs));
        }
    }
    rows
}

/// Panel (c): triangle estimation on ER + the karate club.
pub fn triangles_panel(cfg: &Fig1Config) -> Vec<Row> {
    let er = erdos_renyi(cfg.n, 0.1, cfg.seed);
    let er_truth = er.exact_triangles() as f64;
    let er_adj = er.adjacency();
    let mut rows = Vec::new();
    for &ratio in &cfg.ratios {
        let m = cfg.m_for(ratio);
        for arm in ["digital", "opu"] {
            let errs: Vec<f64> = (0..cfg.trials as u64)
                .map(|t| {
                    let est = match arm {
                        "digital" => estimate_triangles_dense(&cfg.digital(m, t), &er_adj),
                        _ => estimate_triangles_dense(&cfg.opu(m, t), &er_adj),
                    };
                    rel_scalar_error(er_truth, est)
                })
                .collect();
            rows.push(summarize("fig1-triangles", "m/n", ratio, arm, &errs));
        }
    }
    // Real-graph checkpoint: karate club at m/n = 0.75 (n = 34).
    let karate = karate_club();
    let kn = karate.n();
    let ka = karate.adjacency();
    let ktruth = karate.exact_triangles() as f64;
    for arm in ["digital", "opu"] {
        let errs: Vec<f64> = (0..cfg.trials.max(5) as u64)
            .map(|t| {
                let m = 26;
                let est = match arm {
                    "digital" => estimate_triangles_dense(
                        &DigitalSketcher::new(m, kn, cfg.seed ^ t),
                        &ka,
                    ),
                    _ => {
                        let dev = OpuDevice::new(
                            OpuConfig::new(cfg.seed ^ t, m, kn).with_noise(cfg.noise.clone()),
                        );
                        estimate_triangles_dense(&OpuSketcher::new(Arc::new(dev)), &ka)
                    }
                };
                rel_scalar_error(ktruth, est)
            })
            .collect();
        rows.push(summarize("fig1-karate", "m/n", 26.0 / 34.0, arm, &errs));
    }
    rows
}

/// Panel (d): RandSVD rank-k reconstruction error vs k.
pub fn randsvd_panel(cfg: &Fig1Config) -> Vec<Row> {
    let a = matrix_with_spectrum(cfg.n, Spectrum::Exponential { decay: 0.9 }, cfg.seed);
    let ranks = [4usize, 8, 16, 32];
    let mut rows = Vec::new();
    for &k in &ranks {
        // Eckart-Young floor.
        let best = rel_frobenius_error(&a, &linalg::truncated(&a, k));
        rows.push(Row {
            panel: "fig1-randsvd",
            x_label: "rank",
            x: k as f64,
            arm: "exact".into(),
            y: best,
            ci95: 0.0,
            trials: 1,
        });
        for arm in ["digital", "opu"] {
            let errs: Vec<f64> = (0..cfg.trials as u64)
                .map(|t| {
                    let opts = RandSvdOpts {
                        rank: k,
                        oversample: 8,
                        power_iters: 2,
                        ..Default::default()
                    };
                    let m = k + 8;
                    let r = match arm {
                        "digital" => randsvd(&cfg.digital(m, t), &a, opts),
                        _ => randsvd(&cfg.opu(m, t), &a, opts),
                    };
                    let rec = linalg::reconstruct(&r.u, &r.s, &r.vt);
                    rel_frobenius_error(&a, &rec)
                })
                .collect();
            rows.push(summarize("fig1-randsvd", "rank", k as f64, arm, &errs));
        }
    }
    rows
}

/// Full Fig. 1 (all four panels).
pub fn all_panels(cfg: &Fig1Config) -> Vec<Row> {
    let mut rows = matmul_panel(cfg);
    rows.extend(trace_panel(cfg));
    rows.extend(triangles_panel(cfg));
    rows.extend(randsvd_panel(cfg));
    rows
}

/// The paper's headline check: optical ~= numerical. For every (panel, x)
/// pair present in `rows`, the opu arm must be within `tol` absolute error
/// of the digital arm (both are random estimators; they agree in
/// *distribution*, so compare means loosely).
pub fn optical_matches_numerical(rows: &[Row], tol: f64) -> Result<(), String> {
    let mut failures = Vec::new();
    for r in rows.iter().filter(|r| r.arm == "opu") {
        if let Some(d) = rows
            .iter()
            .find(|d| d.arm == "digital" && d.panel == r.panel && (d.x - r.x).abs() < 1e-12)
        {
            let gap = (r.y - d.y).abs();
            let scale = d.y.abs().max(0.02);
            if gap > tol * scale + r.ci95 + d.ci95 {
                failures.push(format!(
                    "{} x={}: opu {:.4} vs digital {:.4}",
                    r.panel, r.x, r.y, d.y
                ));
            }
        }
    }
    if failures.is_empty() {
        Ok(())
    } else {
        Err(failures.join("; "))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Fig1Config {
        Fig1Config {
            n: 64,
            ratios: vec![0.25, 0.75],
            trials: 2,
            seed: 3,
            noise: NoiseModel::realistic(),
        }
    }

    #[test]
    fn matmul_panel_shape_and_decay() {
        let rows = matmul_panel(&tiny());
        assert_eq!(rows.len(), 4); // 2 ratios x 2 arms
        // Error decreases as m/n grows, per arm.
        for arm in ["digital", "opu"] {
            let coarse = rows.iter().find(|r| r.arm == arm && r.x == 0.25).unwrap();
            let fine = rows.iter().find(|r| r.arm == arm && r.x == 0.75).unwrap();
            assert!(fine.y < coarse.y, "{arm}: {} -> {}", coarse.y, fine.y);
        }
    }

    #[test]
    fn optical_matches_numerical_on_matmul() {
        let rows = matmul_panel(&tiny());
        optical_matches_numerical(&rows, 0.75).unwrap();
    }

    #[test]
    fn randsvd_panel_has_exact_floor() {
        let cfg = tiny();
        let rows = randsvd_panel(&cfg);
        for &k in &[4.0, 8.0] {
            let exact = rows
                .iter()
                .find(|r| r.arm == "exact" && r.x == k)
                .unwrap();
            let digital = rows
                .iter()
                .find(|r| r.arm == "digital" && r.x == k)
                .unwrap();
            // Randomized can't beat the optimum (allow tiny slack).
            assert!(digital.y >= exact.y - 1e-9);
        }
    }

    #[test]
    fn trace_panel_runs() {
        let rows = trace_panel(&tiny());
        assert!(rows.iter().all(|r| r.y.is_finite()));
        assert_eq!(rows.len(), 4);
    }
}
