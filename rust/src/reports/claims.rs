//! Scalar claims check — the quotable numbers from §I/§III:
//!   C1  crossover ~1.2e4, GPU OOM past ~7e4;
//!   C2  ~2 orders of magnitude energy advantage (1500 TOPS @ 30 W).

use crate::perfmodel::{self, GpuModel, OpuTimingModel, P100};

/// One claim: paper value vs our model's value.
#[derive(Clone, Debug)]
pub struct Claim {
    pub id: &'static str,
    pub description: &'static str,
    pub paper: f64,
    pub measured: f64,
    /// Acceptable factor (shape reproduction, not absolute numbers).
    pub tolerance_factor: f64,
}

impl Claim {
    pub fn holds(&self) -> bool {
        if self.paper == 0.0 {
            return self.measured == 0.0;
        }
        let ratio = self.measured / self.paper;
        ratio >= 1.0 / self.tolerance_factor && ratio <= self.tolerance_factor
    }
}

pub fn all_claims() -> Vec<Claim> {
    let opu = OpuTimingModel::default();
    let gpu: GpuModel = P100;
    vec![
        Claim {
            id: "C1a",
            description: "OPU/GPU crossover dimension (paper ~1.2e4)",
            paper: 12_000.0,
            measured: perfmodel::crossover_dim(&opu, &gpu) as f64,
            tolerance_factor: 3.0,
        },
        Claim {
            id: "C1b",
            description: "GPU OOM dimension on 16 GB (paper ~7e4)",
            paper: 70_000.0,
            measured: perfmodel::gpu_oom_dim(&gpu) as f64,
            tolerance_factor: 2.0,
        },
        Claim {
            id: "C1c",
            description: "OPU projection latency, ms (paper ~1.2)",
            paper: 1.2,
            measured: opu.projection_ms(1_000_000, 2_000_000),
            tolerance_factor: 5.0,
        },
        Claim {
            id: "C2a",
            description: "OPU effective TOPS at native aperture (paper 1500)",
            paper: 1_500.0,
            measured: opu.effective_tops(1_000_000, 2_000_000),
            tolerance_factor: 8.0,
        },
        Claim {
            id: "C2b",
            description: "energy-efficiency ratio OPU/GPU at n=5e4 (paper ~100x)",
            paper: 100.0,
            measured: perfmodel::energy_ratio(&opu, &gpu, 50_000).unwrap_or(0.0),
            tolerance_factor: 10.0,
        },
    ]
}

pub fn print_claims(claims: &[Claim]) {
    println!("\n== paper claims vs model ==");
    println!(
        "{:<5} {:<55} {:>12} {:>12} {:>6}",
        "id", "claim", "paper", "measured", "ok"
    );
    for c in claims {
        println!(
            "{:<5} {:<55} {:>12.1} {:>12.1} {:>6}",
            c.id,
            c.description,
            c.paper,
            c.measured,
            if c.holds() { "yes" } else { "NO" }
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_claims_hold() {
        for c in all_claims() {
            assert!(
                c.holds(),
                "{} failed: paper {} vs measured {}",
                c.id,
                c.paper,
                c.measured
            );
        }
    }

    #[test]
    fn tolerance_logic() {
        let c = Claim {
            id: "t",
            description: "t",
            paper: 100.0,
            measured: 250.0,
            tolerance_factor: 3.0,
        };
        assert!(c.holds());
        let c2 = Claim { measured: 400.0, ..c };
        assert!(!c2.holds());
    }
}
