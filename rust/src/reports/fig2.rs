//! Fig. 2 — projection time vs dimension, OPU vs GPU.
//!
//! Three series:
//! - `model-opu`  — OPU latency model (published constants; flat + O(n));
//! - `model-gpu`  — P100 roofline (quadratic, OOM cliff past ~7e4);
//! - `pjrt`       — *measured* wall-clock of the AOT proj_xla artifact on
//!   the CPU PJRT client for the buckets we actually ship (the measured
//!   points anchor the model's small-n regime).

use std::time::Instant;

use super::Row;
use crate::linalg::Mat;
use crate::perfmodel::{self, GpuModel, OpuTimingModel, P100};
use crate::rng::Xoshiro256;
use crate::runtime::PjrtHandle;

#[derive(Clone, Debug)]
pub struct Fig2Config {
    /// Dimensions for the model sweep (square n x n).
    pub model_dims: Vec<usize>,
    /// Repetitions for measured points.
    pub reps: usize,
}

impl Default for Fig2Config {
    fn default() -> Self {
        Self {
            model_dims: (8..=17).map(|p| 1usize << p).collect(),
            reps: 5,
        }
    }
}

/// Model sweep (always available).
pub fn model_rows(cfg: &Fig2Config) -> Vec<Row> {
    let opu = OpuTimingModel::default();
    let gpu = P100;
    let mut rows = Vec::new();
    for &n in &cfg.model_dims {
        rows.push(Row {
            panel: "fig2",
            x_label: "n",
            x: n as f64,
            arm: "model-opu".into(),
            y: opu.projection_ms(n, n),
            ci95: 0.0,
            trials: 1,
        });
        let gms = gpu.projection_ms(n, n);
        rows.push(Row {
            panel: "fig2",
            x_label: "n",
            x: n as f64,
            arm: "model-gpu".into(),
            y: gms.unwrap_or(f64::NAN), // NaN = OOM
            ci95: 0.0,
            trials: 1,
        });
    }
    rows
}

/// Measured PJRT points over the shipped proj_xla buckets.
pub fn measured_pjrt_rows(handle: &PjrtHandle, cfg: &Fig2Config) -> anyhow::Result<Vec<Row>> {
    let mut rows = Vec::new();
    let mut rng = Xoshiro256::new(42);
    for (m, n) in handle.buckets("proj_xla")? {
        if m != n / 2 {
            continue; // one representative compression per n
        }
        let r = Mat::gaussian(m, n, 1.0, &mut rng);
        let a = Mat::gaussian(n, n, 1.0, &mut rng);
        // Warm (compile) once.
        let _ = handle.project("proj_xla", r.clone(), a.clone())?;
        let mut stats = crate::stats::Running::new();
        for _ in 0..cfg.reps {
            let t = Instant::now();
            let _ = handle.project("proj_xla", r.clone(), a.clone())?;
            stats.push(t.elapsed().as_secs_f64() * 1e3);
        }
        rows.push(Row {
            panel: "fig2",
            x_label: "n",
            x: n as f64,
            arm: "pjrt".into(),
            y: stats.mean(),
            ci95: stats.ci95(),
            trials: cfg.reps,
        });
    }
    Ok(rows)
}

/// Headline numbers printed beneath the figure.
pub struct Fig2Headline {
    pub crossover_dim: usize,
    pub gpu_oom_dim: usize,
    pub opu_ms_at_1m: f64,
}

pub fn headline() -> Fig2Headline {
    let opu = OpuTimingModel::default();
    let gpu: GpuModel = P100;
    Fig2Headline {
        crossover_dim: perfmodel::crossover_dim(&opu, &gpu),
        gpu_oom_dim: perfmodel::gpu_oom_dim(&gpu),
        opu_ms_at_1m: opu.projection_ms(1_000_000, 1_000_000),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn model_rows_have_oom_nan_tail() {
        let cfg = Fig2Config {
            model_dims: vec![1 << 12, 1 << 17],
            reps: 1,
        };
        let rows = model_rows(&cfg);
        let small_gpu = rows
            .iter()
            .find(|r| r.arm == "model-gpu" && r.x == (1 << 12) as f64)
            .unwrap();
        assert!(small_gpu.y.is_finite());
        let big_gpu = rows
            .iter()
            .find(|r| r.arm == "model-gpu" && r.x == (1 << 17) as f64)
            .unwrap();
        assert!(big_gpu.y.is_nan(), "1e5+ should OOM on 16 GB");
    }

    #[test]
    fn headline_bands() {
        let h = headline();
        assert!((4_000..40_000).contains(&h.crossover_dim));
        assert!((30_000..200_000).contains(&h.gpu_oom_dim));
        assert!(h.opu_ms_at_1m < 10.0);
    }

    #[test]
    fn opu_flat_gpu_quadratic() {
        let cfg = Fig2Config {
            model_dims: vec![1 << 10, 1 << 14],
            reps: 1,
        };
        let rows = model_rows(&cfg);
        let pick = |arm: &str, n: usize| {
            rows.iter()
                .find(|r| r.arm == arm && r.x == n as f64)
                .unwrap()
                .y
        };
        let opu_ratio = pick("model-opu", 1 << 14) / pick("model-opu", 1 << 10);
        let gpu_ratio = pick("model-gpu", 1 << 14) / pick("model-gpu", 1 << 10);
        assert!(opu_ratio < 3.0, "opu should be near-flat: {opu_ratio}");
        assert!(gpu_ratio > 10.0, "gpu should be ~quadratic: {gpu_ratio}");
    }
}
