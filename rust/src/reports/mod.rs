//! Experiment harnesses regenerating every figure/claim in the paper
//! (DESIGN.md §5 experiment index). Shared by the `photon` CLI and the
//! cargo benches so both print identical series.

pub mod claims;
pub mod fig1;
pub mod fig2;

/// One data point of a figure series.
#[derive(Clone, Debug)]
pub struct Row {
    /// Panel / experiment id (e.g. "fig1-matmul").
    pub panel: &'static str,
    /// x-axis meaning (e.g. "m/n").
    pub x_label: &'static str,
    pub x: f64,
    /// Measurement arm: "opu", "digital", "pjrt", "exact", "model-gpu"...
    pub arm: String,
    /// y value (relative error, milliseconds, ...).
    pub y: f64,
    /// 95% CI half-width (0 when single-shot).
    pub ci95: f64,
    pub trials: usize,
}

impl Row {
    pub fn csv_header() -> &'static str {
        "panel,x_label,x,arm,y,ci95,trials"
    }

    pub fn csv(&self) -> String {
        format!(
            "{},{},{},{},{},{},{}",
            self.panel, self.x_label, self.x, self.arm, self.y, self.ci95, self.trials
        )
    }
}

/// Print a series as an aligned table + CSV block.
pub fn print_rows(title: &str, rows: &[Row]) {
    println!("\n== {title} ==");
    println!(
        "{:<16} {:>10} {:<10} {:>14} {:>12} {:>7}",
        "panel", "x", "arm", "y", "ci95", "trials"
    );
    for r in rows {
        println!(
            "{:<16} {:>10.4} {:<10} {:>14.6} {:>12.6} {:>7}",
            r.panel, r.x, r.arm, r.y, r.ci95, r.trials
        );
    }
    println!("\n--- CSV ---");
    println!("{}", Row::csv_header());
    for r in rows {
        println!("{}", r.csv());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_roundtrip_fields() {
        let r = Row {
            panel: "p",
            x_label: "x",
            x: 0.5,
            arm: "opu".into(),
            y: 1.0,
            ci95: 0.1,
            trials: 3,
        };
        assert_eq!(r.csv().split(',').count(), Row::csv_header().split(',').count());
    }
}
