//! [`WireServer`]: the listener side of the network front door.
//!
//! One thread per connection; the first frame must be a `Hello` whose
//! token resolves in the [`TenantRegistry`]. After that the session
//! owns everything it creates — operand handles, streams, in-flight
//! jobs — and the server enforces three tenant boundaries on every
//! frame:
//!
//! - **auth**: unknown tokens (and wrong protocol versions) are
//!   refused with [`StatusCode::AuthFailed`] before anything else runs;
//! - **quota**: uploads and stream footprints charge the tenant's byte
//!   ledger *before* touching the shared
//!   [`OperandStore`](crate::coordinator::OperandStore); a refusal
//!   is the same typed [`StoreError::OverQuota`] an in-process client
//!   sees, and rolls back cleanly;
//! - **QoS**: the tenant's [`QosClass`](crate::coordinator::QosClass) clamps the requested
//!   [`Priority`](crate::coordinator::Priority), so a batch-class
//!   tenant cannot jump the interactive lane.
//!
//! Isolation is by construction: a session can only reference, free,
//! or cancel ids it created (a foreign handle is
//! [`SubmitError::UnknownOperand`], exactly like a stale one), and
//! disconnect releases every session resource deterministically.
//!
//! Graceful shutdown ([`WireServer::shutdown`]) stops accepting,
//! notifies every connection (`ShuttingDown`), lets in-flight jobs
//! drain so each acked submission gets exactly one terminal frame,
//! then closes the engine: queue closed, workers joined, event log
//! synced.

use std::collections::HashMap;
use std::io::{self, ErrorKind};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::ops::ControlFlow;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::events::Event;
use crate::coordinator::render_metrics_text;
use crate::coordinator::request::{CancelHandle, OperandRef, SubmitError};
use crate::coordinator::store::{mat_bytes, OperandId, StoreError};
use crate::coordinator::stream::{StreamError, StreamId, StreamOpts};
use crate::coordinator::tenant::{Tenant, TenantRegistry};
use crate::coordinator::wire::{
    read_frame_poll, write_frame, Frame, StatusCode, WireError, WireMat, WireOptions,
    WireResponse, WireSpec, WireStatus, WIRE_VERSION,
};
use crate::coordinator::Coordinator;

/// How long a blocked socket read waits before the connection thread
/// re-checks the shutdown flag.
const POLL_INTERVAL: Duration = Duration::from_millis(50);

/// A running front door: listener + accept thread + one thread per
/// live connection, all fronting one embedded [`Coordinator`].
pub struct WireServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
    coord: Option<Arc<Coordinator>>,
}

impl WireServer {
    /// Bind `addr` (e.g. `127.0.0.1:0` for an ephemeral port) and start
    /// serving. The server takes ownership of the coordinator; it is
    /// shut down with the server.
    pub fn start(
        coord: Coordinator,
        addr: &str,
        tenants: TenantRegistry,
    ) -> io::Result<WireServer> {
        let listener = TcpListener::bind(addr)?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let coord = Arc::new(coord);
        let tenants = Arc::new(tenants);
        let stop = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));

        let accept = {
            let coord = Arc::clone(&coord);
            let stop = Arc::clone(&stop);
            let conns = Arc::clone(&conns);
            std::thread::Builder::new().name("wire-accept".into()).spawn(move || {
                while !stop.load(Ordering::SeqCst) {
                    match listener.accept() {
                        Ok((stream, _peer)) => {
                            let coord = Arc::clone(&coord);
                            let tenants = Arc::clone(&tenants);
                            let stop = Arc::clone(&stop);
                            let spawned = std::thread::Builder::new()
                                .name("wire-conn".into())
                                .spawn(move || serve_conn(&coord, &tenants, stream, &stop));
                            if let Ok(h) = spawned {
                                conns.lock().unwrap().push(h);
                            }
                        }
                        Err(e) if e.kind() == ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(10));
                        }
                        Err(e) if e.kind() == ErrorKind::Interrupted => {}
                        Err(_) => std::thread::sleep(Duration::from_millis(10)),
                    }
                }
            })?
        };
        Ok(WireServer { addr, stop, accept: Some(accept), conns, coord: Some(coord) })
    }

    /// The bound address (resolves the ephemeral port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The embedded engine (metrics, events, store gauges — tests and
    /// diagnostics).
    pub fn coordinator(&self) -> &Arc<Coordinator> {
        self.coord.as_ref().expect("server is live")
    }

    /// Graceful shutdown: stop accepting, notify and drain every
    /// connection (in-flight jobs resolve; each acked submission gets
    /// exactly one terminal frame), then shut the engine down — queue
    /// closed, workers joined, event log synced.
    pub fn shutdown(mut self) {
        self.stop_and_drain();
    }

    fn stop_and_drain(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        let handles = std::mem::take(&mut *self.conns.lock().unwrap());
        for h in handles {
            let _ = h.join();
        }
        if let Some(coord) = self.coord.take() {
            match Arc::try_unwrap(coord) {
                Ok(c) => c.shutdown(),
                Err(shared) => {
                    // A test still holds the engine; flush the journal
                    // and let the last Arc close the queue on drop.
                    shared.events().sync();
                }
            }
        }
    }
}

impl Drop for WireServer {
    fn drop(&mut self) {
        self.stop_and_drain();
    }
}

/// One tenant-charged reference to a store operand. `charges` holds the
/// tenant-ledger bytes of each session reference (uploads charge the
/// matrix size; aux grants from job results charge 0), so frees release
/// exactly what was reserved, in any order.
struct SessionOperand {
    charges: Vec<usize>,
}

/// Per-connection state: the authenticated tenant plus everything the
/// session owns. Shared pieces (`writer`, `handles`, `jobs`) are also
/// held by waiter threads delivering job results.
struct Session {
    coord: Arc<Coordinator>,
    tenant: Arc<Tenant>,
    writer: Arc<Mutex<TcpStream>>,
    handles: Arc<Mutex<HashMap<u64, SessionOperand>>>,
    /// Stream id → bytes currently charged to the tenant for it.
    streams: HashMap<u64, usize>,
    jobs: Arc<Mutex<HashMap<u64, CancelHandle>>>,
    waiters: Vec<JoinHandle<()>>,
}

fn send(writer: &Mutex<TcpStream>, req: u64, frame: &Frame) -> bool {
    let mut w = writer.lock().unwrap();
    write_frame(&mut *w, req, frame).is_ok()
}

fn serve_conn(
    coord: &Arc<Coordinator>,
    tenants: &TenantRegistry,
    stream: TcpStream,
    stop: &AtomicBool,
) {
    stream.set_nodelay(true).ok();
    if stream.set_read_timeout(Some(POLL_INTERVAL)).is_err() {
        return;
    }
    let writer = match stream.try_clone() {
        Ok(w) => Arc::new(Mutex::new(w)),
        Err(_) => return,
    };
    let mut rd = stream;

    let tenant = match authenticate(&mut rd, &writer, tenants, stop) {
        None => return,
        Some(Authed::Worker { req }) => {
            // The connection is a map worker dialing in, not a client:
            // it joins the cluster plane and speaks the partition
            // protocol until it disconnects.
            serve_worker(coord, rd, &writer, req, stop);
            return;
        }
        Some(Authed::Client(t)) => t,
    };
    coord.events().append(Event::TenantConnected { tenant: tenant.name.to_string() });

    let mut session = Session {
        coord: Arc::clone(coord),
        tenant,
        writer,
        handles: Arc::new(Mutex::new(HashMap::new())),
        streams: HashMap::new(),
        jobs: Arc::new(Mutex::new(HashMap::new())),
        waiters: Vec::new(),
    };

    while !stop.load(Ordering::SeqCst) {
        let (req, frame) = match read_frame_poll(&mut rd, stop) {
            Ok(None) => continue,
            Ok(Some(x)) => x,
            Err(WireError::Closed) | Err(WireError::Io(_)) => break,
            Err(e) => {
                // Codec-level corruption: refuse typed, then drop the
                // connection (the byte stream may be desynced).
                let status = WireStatus::with_detail(StatusCode::BadFrame, e.to_string());
                send(&session.writer, 0, &Frame::Status(status));
                break;
            }
        };
        if session.handle(req, frame).is_break() {
            break;
        }
    }

    // Shutdown notice first so the client stops submitting, then drain:
    // every acked job still delivers exactly one JobDone/Status.
    if stop.load(Ordering::SeqCst) {
        send(&session.writer, 0, &Frame::ShuttingDown);
    }
    for w in session.waiters.drain(..) {
        let _ = w.join();
    }
    session.release_all();
    session
        .coord
        .events()
        .append(Event::TenantDisconnected { tenant: session.tenant.name.to_string() });
}

/// What a successful handshake produced: a tenant-bound client session,
/// or a map worker joining the cluster plane (`req` echoes its
/// `WorkerHello` so `WorkerOk` lands on the waiting request).
enum Authed {
    Client(Arc<Tenant>),
    Worker { req: u64 },
}

/// Pre-session handshake: the first frame must be a `Hello` (client) or
/// `WorkerHello` (map worker) with the right protocol version and a
/// known token — workers authenticate against the same registry, so an
/// open port cannot be joined by an unauthenticated node.
fn authenticate(
    rd: &mut TcpStream,
    writer: &Mutex<TcpStream>,
    tenants: &TenantRegistry,
    stop: &AtomicBool,
) -> Option<Authed> {
    loop {
        let (req, frame) = match read_frame_poll(rd, stop) {
            Ok(None) => {
                if stop.load(Ordering::SeqCst) {
                    return None;
                }
                continue;
            }
            Ok(Some(x)) => x,
            Err(_) => return None,
        };
        let refuse = |detail: String| {
            let status = WireStatus::with_detail(StatusCode::AuthFailed, detail);
            send(writer, req, &Frame::Status(status));
            None
        };
        return match frame {
            Frame::Hello { version, token } => {
                if version != WIRE_VERSION {
                    return refuse(format!(
                        "protocol version {version} (server speaks {WIRE_VERSION})"
                    ));
                }
                match tenants.authenticate(&token) {
                    Some(t) => {
                        let hello = Frame::HelloOk {
                            tenant: t.name.to_string(),
                            qos: t.qos.code(),
                            quota: t.quota() as u64,
                        };
                        if !send(writer, req, &hello) {
                            return None;
                        }
                        Some(Authed::Client(t))
                    }
                    None => refuse("unknown token".into()),
                }
            }
            Frame::WorkerHello { version, token } => {
                if version != WIRE_VERSION {
                    return refuse(format!(
                        "protocol version {version} (server speaks {WIRE_VERSION})"
                    ));
                }
                match tenants.authenticate(&token) {
                    Some(_) => Some(Authed::Worker { req }),
                    None => refuse("unknown token".into()),
                }
            }
            _ => refuse("first frame must be Hello".into()),
        };
    }
}

/// A registered map worker's connection loop: hand every partition
/// frame to the cluster plane; on any exit path the plane is told the
/// worker is gone so in-flight streams fail typed instead of hanging.
fn serve_worker(
    coord: &Arc<Coordinator>,
    mut rd: TcpStream,
    writer: &Arc<Mutex<TcpStream>>,
    hello_req: u64,
    stop: &AtomicBool,
) {
    let peer = rd.peer_addr().map(|a| a.to_string()).unwrap_or_else(|_| "worker".into());
    let (id, seed, chunk_rows) = coord.cluster().register_worker(peer, Arc::clone(writer));
    let ok = Frame::WorkerOk { worker: id, seed, chunk_rows: chunk_rows as u64 };
    if !send(writer, hello_req, &ok) {
        coord.cluster().worker_lost(id);
        return;
    }
    while !stop.load(Ordering::SeqCst) {
        match read_frame_poll(&mut rd, stop) {
            Ok(None) => continue,
            Ok(Some((_req, Frame::Goodbye))) => break,
            Ok(Some((_req, frame))) => coord.cluster().worker_frame(id, frame),
            Err(_) => break,
        }
    }
    if stop.load(Ordering::SeqCst) {
        send(writer, 0, &Frame::ShuttingDown);
    }
    coord.cluster().worker_lost(id);
}

/// Static frame label for the telemetry journal (`&'static str` so
/// [`Event::WireHandled`] never allocates per request).
fn frame_kind(frame: &Frame) -> &'static str {
    match frame {
        Frame::Upload { .. } => "upload",
        Frame::FreeOperand { .. } => "free_operand",
        Frame::BeginStream { .. } => "begin_stream",
        Frame::AppendStream { .. } => "append_stream",
        Frame::SealStream { .. } => "seal_stream",
        Frame::FreeStream { .. } => "free_stream",
        Frame::Submit { .. } => "submit",
        Frame::Cancel { .. } => "cancel",
        Frame::Report => "report",
        Frame::Metrics => "metrics",
        Frame::Goodbye => "goodbye",
        _ => "other",
    }
}

/// Satellite isolation on the report surface: a remote tenant sees the
/// global gauges plus its *own* `tenant[...]` lines, never a peer's.
/// (The in-process `Metrics::report` stays unfiltered — it is the
/// operator's view.)
fn tenant_report(full: &str, tenant: &str) -> String {
    let own = format!("tenant[{tenant}]");
    full.lines()
        .filter(|l| !l.starts_with("tenant[") || l.starts_with(own.as_str()))
        .collect::<Vec<_>>()
        .join("\n")
}

impl Session {
    /// Route one authenticated frame, journaling a [`Event::WireHandled`]
    /// span (tenant, frame kind, wall time) when telemetry is armed.
    fn handle(&mut self, req: u64, frame: Frame) -> ControlFlow<()> {
        let clock = self.coord.telemetry().is_some().then(Instant::now);
        let kind = frame_kind(&frame);
        let flow = self.dispatch(req, frame);
        if let Some(t0) = clock {
            self.coord.events().append(Event::WireHandled {
                tenant: self.tenant.name.to_string(),
                kind,
                dur_us: t0.elapsed().as_micros() as u64,
            });
        }
        flow
    }

    fn dispatch(&mut self, req: u64, frame: Frame) -> ControlFlow<()> {
        match frame {
            Frame::Upload { mat } => self.upload(req, &mat),
            Frame::FreeOperand { id } => self.free_operand(req, id),
            Frame::BeginStream { rows, cols, chunk_rows, sketch_m, fd_rank, range_cap } => {
                let opts = StreamOpts {
                    chunk_rows: (chunk_rows != 0).then_some(chunk_rows as usize),
                    sketch_m: sketch_m as usize,
                    fd_rank: fd_rank as usize,
                    range_cap: range_cap as usize,
                };
                self.begin_stream(req, rows as usize, cols as usize, opts);
            }
            Frame::AppendStream { id, rows } => self.append_stream(req, id, &rows),
            Frame::SealStream { id } => self.seal_stream(req, id),
            Frame::FreeStream { id } => self.free_stream(req, id),
            Frame::Submit { spec, opts } => self.submit(req, &spec, &opts),
            Frame::Cancel { job } => {
                let handle = self.jobs.lock().unwrap().get(&job).cloned();
                let cancelled = match handle {
                    Some(h) => h.fire(job),
                    None => false, // finished, foreign, or never acked
                };
                self.send(req, &Frame::CancelOk { cancelled });
            }
            Frame::Report => {
                let text = tenant_report(&self.coord.metrics.report(), &self.tenant.name);
                self.send(req, &Frame::ReportText { text });
            }
            Frame::Metrics => {
                // Same bytes `GET /metrics` serves: the armed registry's
                // exposition, or the bare counter families when the
                // telemetry plane is off.
                let text = match self.coord.telemetry() {
                    Some(t) => t.render(),
                    None => render_metrics_text(&self.coord.metrics),
                };
                self.send(req, &Frame::MetricsText { text });
            }
            Frame::Goodbye => return ControlFlow::Break(()),
            Frame::Hello { .. } | Frame::WorkerHello { .. } => {
                self.refuse(req, StatusCode::BadFrame, "already authenticated");
            }
            Frame::SlotSummary { .. }
            | Frame::PartitionSealed { .. }
            | Frame::PartitionFreed { .. } => {
                self.refuse(req, StatusCode::BadFrame, "worker-role frame on a client session");
            }
            Frame::Unknown { tag } => {
                let mut status =
                    WireStatus::with_detail(StatusCode::UnknownTag, "unassigned frame tag");
                status.a = u64::from(tag);
                self.send(req, &Frame::Status(status));
            }
            _ => {
                self.refuse(req, StatusCode::BadFrame, "server-role frame from client");
            }
        }
        ControlFlow::Continue(())
    }

    fn send(&self, req: u64, frame: &Frame) -> bool {
        send(&self.writer, req, frame)
    }

    fn refuse(&self, req: u64, code: StatusCode, detail: &str) {
        self.send(req, &Frame::Status(WireStatus::with_detail(code, detail)));
    }

    fn quota_refused(&self, req: u64, e: &StoreError) {
        self.coord.metrics.tenant_quota_rejected(&self.tenant.name);
        self.send(req, &Frame::Status(WireStatus::from_store(e)));
    }

    fn upload(&mut self, req: u64, mat: &WireMat) {
        let m = match mat.to_mat() {
            Ok(m) => m,
            Err(e) => return self.refuse(req, StatusCode::BadFrame, &e.to_string()),
        };
        let bytes = mat_bytes(&m);
        // Tenant ledger first: the shared store is never touched past a
        // tenant's quota, so one tenant at its cap cannot evict or
        // crowd another (see the isolation test).
        if let Err(e) = self.tenant.reserve(bytes) {
            return self.quota_refused(req, &e);
        }
        match self.coord.store().insert(Arc::new(m)) {
            Ok(id) => {
                self.coord.metrics.tenant_operand_bytes(&self.tenant.name, bytes as u64);
                let mut h = self.handles.lock().unwrap();
                h.entry(id.0)
                    .or_insert_with(|| SessionOperand { charges: Vec::new() })
                    .charges
                    .push(bytes);
                drop(h);
                self.send(req, &Frame::OperandOk { id: id.0, bytes: bytes as u64 });
            }
            Err(e) => {
                // Global store quota: roll the tenant charge back.
                self.tenant.release(bytes);
                self.quota_refused(req, &e);
            }
        }
    }

    fn free_operand(&mut self, req: u64, id: u64) {
        let charge = {
            let mut h = self.handles.lock().unwrap();
            match h.get_mut(&id) {
                None => {
                    drop(h);
                    let e = SubmitError::UnknownOperand(OperandId(id));
                    self.send(req, &Frame::Status(WireStatus::from_submit(&e)));
                    return;
                }
                Some(so) => {
                    let charge = so.charges.pop().unwrap_or(0);
                    if so.charges.is_empty() {
                        h.remove(&id);
                    }
                    charge
                }
            }
        };
        let existed = self.coord.free_operand(OperandId(id));
        self.tenant.release(charge);
        self.send(req, &Frame::Freed { existed });
    }

    fn begin_stream(&mut self, req: u64, rows: usize, cols: usize, opts: StreamOpts) {
        let sid = match self.coord.begin_stream(rows, cols, opts) {
            Ok(sid) => sid,
            Err(e) => {
                self.send(req, &Frame::Status(WireStatus::from_stream(&e)));
                return;
            }
        };
        let footprint = self.coord.streams().footprint(sid).unwrap_or(0);
        if let Err(e) = self.tenant.reserve(footprint) {
            self.coord.free_stream(sid);
            return self.quota_refused(req, &e);
        }
        self.coord.metrics.tenant_operand_bytes(&self.tenant.name, footprint as u64);
        self.streams.insert(sid.0, footprint);
        self.send(req, &Frame::StreamOk { id: sid.0 });
    }

    fn append_stream(&mut self, req: u64, id: u64, rows: &WireMat) {
        if !self.streams.contains_key(&id) {
            let e = StreamError::UnknownStream(StreamId(id));
            self.send(req, &Frame::Status(WireStatus::from_stream(&e)));
            return;
        }
        let m = match rows.to_mat() {
            Ok(m) => m,
            Err(e) => return self.refuse(req, StatusCode::BadFrame, &e.to_string()),
        };
        match self.coord.append_stream(StreamId(id), &m) {
            Ok(()) => {
                self.send(req, &Frame::Ack);
            }
            Err(e) => {
                self.send(req, &Frame::Status(WireStatus::from_stream(&e)));
            }
        }
    }

    fn seal_stream(&mut self, req: u64, id: u64) {
        if !self.streams.contains_key(&id) {
            let e = StreamError::UnknownStream(StreamId(id));
            self.send(req, &Frame::Status(WireStatus::from_stream(&e)));
            return;
        }
        match self.coord.seal_stream(StreamId(id)) {
            Ok(()) => {
                // Sealing usually shrinks the footprint (chunk buffer
                // dropped); give the difference back to the ledger.
                let now = self.coord.streams().footprint(StreamId(id)).unwrap_or(0);
                if let Some(charged) = self.streams.get_mut(&id) {
                    if now < *charged {
                        self.tenant.release(*charged - now);
                        *charged = now;
                    } else if now > *charged && self.tenant.reserve(now - *charged).is_ok() {
                        *charged = now;
                    }
                }
                self.send(req, &Frame::Ack);
            }
            Err(e) => {
                self.send(req, &Frame::Status(WireStatus::from_stream(&e)));
            }
        }
    }

    fn free_stream(&mut self, req: u64, id: u64) {
        let Some(charged) = self.streams.remove(&id) else {
            let e = StreamError::UnknownStream(StreamId(id));
            self.send(req, &Frame::Status(WireStatus::from_stream(&e)));
            return;
        };
        let existed = self.coord.free_stream(StreamId(id));
        self.tenant.release(charged);
        self.send(req, &Frame::Freed { existed });
    }

    fn submit(&mut self, req: u64, spec: &WireSpec, opts: &WireOptions) {
        let spec = match spec.to_spec() {
            Ok(s) => s,
            Err(e) => return self.refuse(req, StatusCode::BadFrame, &e.to_string()),
        };
        let mut opts = match opts.to_opts() {
            Ok(o) => o,
            Err(e) => return self.refuse(req, StatusCode::BadFrame, &e.to_string()),
        };
        // A session may only reference ids it owns: a foreign (or
        // stale) handle is indistinguishable from an unknown one.
        let spec = {
            let h = self.handles.lock().unwrap();
            let streams = &self.streams;
            let checked = spec.try_map_refs(&mut |r| {
                match &r {
                    OperandRef::Handle(id) if !h.contains_key(&id.0) => {
                        return Err(SubmitError::UnknownOperand(*id));
                    }
                    OperandRef::Stream(id) if !streams.contains_key(&id.0) => {
                        return Err(SubmitError::UnknownStream(*id));
                    }
                    OperandRef::Stage(i) => {
                        // Plans are not part of the wire surface yet.
                        return Err(SubmitError::StageRefOutsidePlan(*i));
                    }
                    _ => {}
                }
                Ok(r)
            });
            match checked {
                Ok(s) => s,
                Err(e) => {
                    self.send(req, &Frame::Status(WireStatus::from_submit(&e)));
                    return;
                }
            }
        };
        opts.priority = self.tenant.qos.clamp(opts.priority);
        let tenant_name = Arc::clone(&self.tenant.name);
        match self.coord.submit_spec_as(Some(tenant_name), spec, opts) {
            Err(e) => {
                self.send(req, &Frame::Status(WireStatus::from_submit(&e)));
            }
            Ok(ticket) => {
                let job = ticket.id;
                self.jobs.lock().unwrap().insert(job, ticket.cancel_handle());
                self.send(req, &Frame::Submitted { job });
                // The waiter owns the ticket: exactly one terminal
                // frame per acked job, even across shutdown.
                let writer = Arc::clone(&self.writer);
                let jobs = Arc::clone(&self.jobs);
                let handles = Arc::clone(&self.handles);
                let spawned = std::thread::Builder::new().name("wire-wait".into()).spawn(
                    move || {
                        let outcome = ticket.wait();
                        jobs.lock().unwrap().remove(&job);
                        let frame = match outcome {
                            Ok(resp) => {
                                // Published aux operands (e.g. a range
                                // basis) become session-owned handles,
                                // uncharged: they are engine results,
                                // not tenant uploads.
                                let mut h = handles.lock().unwrap();
                                for (_, id) in &resp.aux {
                                    h.entry(id.0)
                                        .or_insert_with(|| SessionOperand {
                                            charges: Vec::new(),
                                        })
                                        .charges
                                        .push(0);
                                }
                                drop(h);
                                Frame::JobDone(WireResponse::from_response(&resp))
                            }
                            Err(e) => Frame::Status(WireStatus::from_job(&e)),
                        };
                        send(&writer, req, &frame);
                    },
                );
                if let Ok(h) = spawned {
                    self.waiters.push(h);
                }
            }
        }
    }

    /// Disconnect cleanup: drop every session reference and return the
    /// charged bytes to the tenant's ledger.
    fn release_all(&mut self) {
        let drained: Vec<(u64, SessionOperand)> =
            self.handles.lock().unwrap().drain().collect();
        for (id, so) in drained {
            for charge in so.charges {
                self.coord.free_operand(OperandId(id));
                self.tenant.release(charge);
            }
        }
        for (id, charged) in self.streams.drain() {
            self.coord.free_stream(StreamId(id));
            self.tenant.release(charged);
        }
    }
}
