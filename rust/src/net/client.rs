//! [`WireClient`]: the library client of the network front door.
//!
//! One TCP connection, many concurrent calls: every request carries a
//! client-chosen `req_id`, a dedicated reader thread routes response
//! frames back to the waiting caller by that id, and submissions hand
//! back a [`RemoteTicket`] whose `wait`/`try_wait`/`cancel` mirror the
//! in-process [`Ticket`](crate::coordinator::Ticket) — including the
//! same *typed* errors: a refused admission surfaces as the identical
//! [`SubmitError`] the embedded engine raised, reconstructed from the
//! wire status.

use std::collections::HashMap;
use std::fmt;
use std::io::Write;
use std::net::{Shutdown, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;

use crate::coordinator::request::{JobError, JobResponse, JobSpec, SubmitError, SubmitOptions};
use crate::coordinator::store::{OperandId, StoreError};
use crate::coordinator::stream::{StreamId, StreamOpts};
use crate::coordinator::wire::{
    encode_frame, read_frame, Frame, StatusCode, WireError, WireMat, WireOptions, WireSpec,
    WireStatus, WIRE_VERSION,
};
use crate::coordinator::QosClass;
use crate::linalg::Mat;

/// Typed client-side failure of a remote call.
#[derive(Clone, Debug, PartialEq)]
pub enum ClientError {
    /// Socket-level failure (connect, read, write, or a codec error on
    /// a received frame).
    Transport(String),
    /// The server refused the token (or the protocol version).
    Auth(String),
    /// The server refused with a store error — the same typed
    /// [`StoreError`] an in-process `upload` raises (per-tenant quota
    /// refusals arrive here too).
    Store(StoreError),
    /// The server refused a submission — the same typed
    /// [`SubmitError`] an in-process `submit_spec` raises.
    Submit(SubmitError),
    /// Any other refusal, with its wire status (stream sizing errors,
    /// unknown-tag notices, shutdown).
    Denied(WireStatus),
    /// The server answered with a frame the protocol does not allow
    /// for this request.
    Protocol(String),
}

impl fmt::Display for ClientError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ClientError::Transport(m) => write!(f, "transport: {m}"),
            ClientError::Auth(m) => write!(f, "authentication refused: {m}"),
            ClientError::Store(e) => write!(f, "{e}"),
            ClientError::Submit(e) => write!(f, "{e}"),
            ClientError::Denied(s) => write!(f, "refused: {s}"),
            ClientError::Protocol(m) => write!(f, "protocol: {m}"),
        }
    }
}

impl std::error::Error for ClientError {}

/// Map a refusal status to the most specific typed error it encodes.
fn denied(s: WireStatus) -> ClientError {
    if s.code == StatusCode::AuthFailed {
        return ClientError::Auth(s.detail);
    }
    if let Some(e) = s.try_store_error() {
        return ClientError::Store(e);
    }
    if let Some(e) = s.try_submit_error() {
        return ClientError::Submit(e);
    }
    ClientError::Denied(s)
}

struct Inner {
    writer: Mutex<TcpStream>,
    /// In-flight requests: req_id → the caller's response channel. The
    /// reader thread removes an entry when it delivers a terminal
    /// frame; `Submitted` is the one non-terminal response (the entry
    /// stays armed for the job's later `JobDone`/`Status`).
    pending: Mutex<HashMap<u64, mpsc::Sender<Frame>>>,
    next_req: AtomicU64,
    /// Set when the server announced shutdown or the reader died;
    /// subsequent calls fail fast instead of writing into a dead pipe.
    closed: AtomicBool,
}

impl Inner {
    fn send(&self, req: u64, frame: &Frame) -> Result<(), ClientError> {
        if self.closed.load(Ordering::SeqCst) {
            return Err(ClientError::Transport("connection closed".into()));
        }
        let bytes = encode_frame(req, frame);
        let mut w = self.writer.lock().unwrap();
        w.write_all(&bytes)
            .and_then(|()| w.flush())
            .map_err(|e| ClientError::Transport(e.to_string()))
    }

    /// Register a request and write its frame; the returned receiver
    /// yields that request's response frames.
    fn call(&self, frame: &Frame) -> Result<(u64, mpsc::Receiver<Frame>), ClientError> {
        let req = self.next_req.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = mpsc::channel();
        self.pending.lock().unwrap().insert(req, tx);
        if let Err(e) = self.send(req, frame) {
            self.pending.lock().unwrap().remove(&req);
            return Err(e);
        }
        Ok((req, rx))
    }

    /// One-shot request: write, then block for the single response.
    fn request(&self, frame: &Frame) -> Result<Frame, ClientError> {
        let (_req, rx) = self.call(frame)?;
        rx.recv().map_err(|_| ClientError::Transport("connection lost".into()))
    }

    fn drop_pending(&self) {
        // Dropping the senders disconnects every waiting receiver.
        self.pending.lock().unwrap().clear();
    }
}

/// A connected, authenticated session with a remote coordinator.
pub struct WireClient {
    inner: Arc<Inner>,
    reader: Option<JoinHandle<()>>,
    tenant: String,
    qos: QosClass,
    quota: usize,
}

impl WireClient {
    /// Connect and authenticate. The `Hello` exchange is synchronous —
    /// on return the session is live and every typed server refusal
    /// maps back to the matching [`ClientError`].
    pub fn connect(addr: impl ToSocketAddrs, token: &str) -> Result<Self, ClientError> {
        let stream =
            TcpStream::connect(addr).map_err(|e| ClientError::Transport(e.to_string()))?;
        stream.set_nodelay(true).ok();
        let mut rd = stream.try_clone().map_err(|e| ClientError::Transport(e.to_string()))?;

        // Authenticate before spawning the reader: a refused token must
        // surface from `connect`, not from a background thread.
        let hello = encode_frame(1, &Frame::Hello { version: WIRE_VERSION, token: token.into() });
        {
            let mut w = &stream;
            w.write_all(&hello)
                .and_then(|()| w.flush())
                .map_err(|e| ClientError::Transport(e.to_string()))?;
        }
        let (tenant, qos, quota) = match read_frame(&mut rd) {
            Ok((_, Frame::HelloOk { tenant, qos, quota })) => {
                let qos = QosClass::from_code(qos)
                    .ok_or_else(|| ClientError::Protocol(format!("bad qos code {qos}")))?;
                (tenant, qos, quota as usize)
            }
            Ok((_, Frame::Status(s))) => return Err(denied(s)),
            Ok((_, other)) => {
                return Err(ClientError::Protocol(format!(
                    "expected HelloOk, got tag {}",
                    other.tag()
                )))
            }
            Err(e) => return Err(ClientError::Transport(e.to_string())),
        };

        let inner = Arc::new(Inner {
            writer: Mutex::new(stream),
            pending: Mutex::new(HashMap::new()),
            next_req: AtomicU64::new(2),
            closed: AtomicBool::new(false),
        });
        let reader = {
            let inner = Arc::clone(&inner);
            std::thread::Builder::new()
                .name("wire-client-reader".into())
                .spawn(move || reader_loop(&inner, &mut rd))
                .map_err(|e| ClientError::Transport(e.to_string()))?
        };
        Ok(Self { inner, reader: Some(reader), tenant, qos, quota })
    }

    /// Tenant name the server authenticated this session as.
    pub fn tenant(&self) -> &str {
        &self.tenant
    }

    /// QoS class the session's submissions are clamped to.
    pub fn qos(&self) -> QosClass {
        self.qos
    }

    /// The tenant's byte quota (`usize::MAX` = unbounded).
    pub fn quota(&self) -> usize {
        self.quota
    }

    /// Upload an operand; the handle is valid for this session's
    /// submissions (content-dedup happens server-side).
    pub fn upload(&self, m: &Mat) -> Result<OperandId, ClientError> {
        match self.inner.request(&Frame::Upload { mat: WireMat::from_mat(m) })? {
            Frame::OperandOk { id, .. } => Ok(OperandId(id)),
            Frame::Status(s) => Err(denied(s)),
            other => Err(Self::unexpected(&other)),
        }
    }

    /// Drop the session's reference to an uploaded operand.
    pub fn free_operand(&self, id: OperandId) -> Result<bool, ClientError> {
        match self.inner.request(&Frame::FreeOperand { id: id.0 })? {
            Frame::Freed { existed } => Ok(existed),
            Frame::Status(s) => Err(denied(s)),
            other => Err(Self::unexpected(&other)),
        }
    }

    /// Open a streamed operand (see
    /// [`Coordinator::begin_stream`](crate::coordinator::Coordinator::begin_stream)).
    pub fn begin_stream(
        &self,
        rows: usize,
        cols: usize,
        opts: StreamOpts,
    ) -> Result<StreamId, ClientError> {
        let frame = Frame::BeginStream {
            rows: rows as u64,
            cols: cols as u64,
            chunk_rows: opts.chunk_rows.unwrap_or(0) as u64,
            sketch_m: opts.sketch_m as u64,
            fd_rank: opts.fd_rank as u64,
            range_cap: opts.range_cap as u64,
        };
        match self.inner.request(&frame)? {
            Frame::StreamOk { id } => Ok(StreamId(id)),
            Frame::Status(s) => Err(denied(s)),
            other => Err(Self::unexpected(&other)),
        }
    }

    /// Append rows to an open stream.
    pub fn append_stream(&self, id: StreamId, rows: &Mat) -> Result<(), ClientError> {
        let frame = Frame::AppendStream { id: id.0, rows: WireMat::from_mat(rows) };
        match self.inner.request(&frame)? {
            Frame::Ack => Ok(()),
            Frame::Status(s) => Err(denied(s)),
            other => Err(Self::unexpected(&other)),
        }
    }

    /// Seal a stream; one-pass jobs may now reference it.
    pub fn seal_stream(&self, id: StreamId) -> Result<(), ClientError> {
        match self.inner.request(&Frame::SealStream { id: id.0 })? {
            Frame::Ack => Ok(()),
            Frame::Status(s) => Err(denied(s)),
            other => Err(Self::unexpected(&other)),
        }
    }

    /// Free a stream (sealed or not).
    pub fn free_stream(&self, id: StreamId) -> Result<bool, ClientError> {
        match self.inner.request(&Frame::FreeStream { id: id.0 })? {
            Frame::Freed { existed } => Ok(existed),
            Frame::Status(s) => Err(denied(s)),
            other => Err(Self::unexpected(&other)),
        }
    }

    /// Submit a job. Returns as soon as the server acknowledges
    /// admission; the result streams back later through the ticket. A
    /// typed refusal ([`SubmitError::Busy`] backpressure, quota, stale
    /// handles) surfaces here, exactly as in-process.
    pub fn submit(
        &self,
        spec: &JobSpec,
        opts: SubmitOptions,
    ) -> Result<RemoteTicket, ClientError> {
        let frame = Frame::Submit {
            spec: WireSpec::from_spec(spec),
            opts: WireOptions::from_opts(&opts),
        };
        let (_req, rx) = self.inner.call(&frame)?;
        match rx.recv() {
            Ok(Frame::Submitted { job }) => Ok(RemoteTicket { job, rx }),
            Ok(Frame::Status(s)) => Err(denied(s)),
            Ok(other) => Err(Self::unexpected(&other)),
            Err(_) => Err(ClientError::Transport("connection lost".into())),
        }
    }

    /// Submit and block for the result (the remote `run_spec`).
    pub fn run(&self, spec: &JobSpec, opts: SubmitOptions) -> Result<JobResponse, JobError> {
        let ticket = self.submit(spec, opts).map_err(|e| match e {
            ClientError::Submit(SubmitError::Closed) => JobError::QueueClosed,
            ClientError::Submit(se) => JobError::Rejected(se),
            other => JobError::Failed(other.to_string()),
        })?;
        ticket.wait()
    }

    /// Best-effort remote cancel of a job this session submitted.
    /// `true` means the job was still queued and will never run.
    pub fn cancel(&self, job: u64) -> Result<bool, ClientError> {
        match self.inner.request(&Frame::Cancel { job })? {
            Frame::CancelOk { cancelled } => Ok(cancelled),
            Frame::Status(s) => Err(denied(s)),
            other => Err(Self::unexpected(&other)),
        }
    }

    /// The server's metrics report (global gauges plus this tenant's
    /// own `tenant[...]` lines — peers' lines are filtered server-side).
    pub fn report(&self) -> Result<String, ClientError> {
        match self.inner.request(&Frame::Report)? {
            Frame::ReportText { text } => Ok(text),
            Frame::Status(s) => Err(denied(s)),
            other => Err(Self::unexpected(&other)),
        }
    }

    /// The server's Prometheus text exposition — the same bytes its
    /// `GET /metrics` endpoint serves, fetched through the authed
    /// session instead of a separate scrape port.
    pub fn metrics(&self) -> Result<String, ClientError> {
        match self.inner.request(&Frame::Metrics)? {
            Frame::MetricsText { text } => Ok(text),
            Frame::Status(s) => Err(denied(s)),
            other => Err(Self::unexpected(&other)),
        }
    }

    fn unexpected(frame: &Frame) -> ClientError {
        ClientError::Protocol(format!("unexpected response frame tag {}", frame.tag()))
    }
}

impl Drop for WireClient {
    fn drop(&mut self) {
        // Best-effort goodbye, then unblock the reader and join it.
        let _ = self.inner.send(0, &Frame::Goodbye);
        self.inner.closed.store(true, Ordering::SeqCst);
        if let Ok(w) = self.inner.writer.lock() {
            let _ = w.shutdown(Shutdown::Both);
        }
        if let Some(h) = self.reader.take() {
            let _ = h.join();
        }
    }
}

/// Routes incoming frames to their waiting callers until the socket
/// closes. `Submitted` keeps its request armed (the job's terminal
/// `JobDone`/`Status` arrives later on the same req id); everything
/// else completes its request.
fn reader_loop(inner: &Inner, rd: &mut TcpStream) {
    loop {
        match read_frame(rd) {
            Ok((req, frame)) => {
                if req == 0 {
                    // Unsolicited server notice (ShuttingDown): flag the
                    // session; in-flight waiters resolve when the
                    // server closes the socket after its drain.
                    if frame == Frame::ShuttingDown {
                        inner.closed.store(true, Ordering::SeqCst);
                    }
                    continue;
                }
                let keep = matches!(frame, Frame::Submitted { .. });
                let mut pending = inner.pending.lock().unwrap();
                let sender = if keep {
                    pending.get(&req).cloned()
                } else {
                    pending.remove(&req)
                };
                drop(pending);
                if let Some(tx) = sender {
                    let _ = tx.send(frame);
                }
            }
            Err(WireError::Closed) | Err(WireError::Io(_)) => break,
            Err(_) => break, // framing corruption: the session is unusable
        }
    }
    inner.closed.store(true, Ordering::SeqCst);
    inner.drop_pending();
}

/// In-flight handle for a remotely submitted job — the wire twin of
/// [`Ticket`](crate::coordinator::Ticket).
pub struct RemoteTicket {
    job: u64,
    rx: mpsc::Receiver<Frame>,
}

impl RemoteTicket {
    /// Server-assigned job id (valid for [`WireClient::cancel`]).
    pub fn id(&self) -> u64 {
        self.job
    }

    /// Block until the job completes, with the same typed outcomes as
    /// the in-process ticket: a cancelled job resolves to
    /// [`JobError::Cancelled`], a lost connection to
    /// [`JobError::Dropped`].
    pub fn wait(self) -> Result<JobResponse, JobError> {
        match self.rx.recv() {
            Ok(frame) => Self::terminal(frame),
            Err(_) => Err(JobError::Dropped),
        }
    }

    /// Non-blocking poll.
    pub fn try_wait(&self) -> Option<Result<JobResponse, JobError>> {
        match self.rx.try_recv() {
            Ok(frame) => Some(Self::terminal(frame)),
            Err(mpsc::TryRecvError::Empty) => None,
            Err(mpsc::TryRecvError::Disconnected) => Some(Err(JobError::Dropped)),
        }
    }

    fn terminal(frame: Frame) -> Result<JobResponse, JobError> {
        match frame {
            Frame::JobDone(r) => {
                r.to_response().map_err(|e| JobError::Failed(format!("bad response frame: {e}")))
            }
            Frame::Status(s) => {
                Err(s.try_job_error().unwrap_or_else(|| JobError::Failed(s.to_string())))
            }
            other => Err(JobError::Failed(format!("unexpected frame tag {}", other.tag()))),
        }
    }
}
