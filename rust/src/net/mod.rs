//! The network front door: a framed TCP serving plane over the session
//! API, with multi-tenant auth, quotas, and QoS.
//!
//! ```text
//!  WireClient ──TCP──▶ WireServer ──▶ Coordinator (embedded engine)
//!   upload/stream       per-conn        store / streams / queue /
//!   submit/cancel       thread, auth,   batcher / pool / events
//!   RemoteTicket        tenant ledger
//! ```
//!
//! - [`server`] — the listener (`photon serve --listen ADDR --tenants
//!   FILE`): one thread per connection, first frame must authenticate,
//!   every session resource (operand handles, streams, in-flight jobs)
//!   is owned by the connection and freed on disconnect; per-tenant
//!   quota ledgers and QoS clamping sit in front of the embedded
//!   [`Coordinator`](crate::coordinator::Coordinator);
//! - [`client`] — [`WireClient`]: a synchronous session handle
//!   multiplexing concurrent calls over one socket (a reader thread
//!   routes frames by request id), with [`RemoteTicket`] mirroring the
//!   in-process `Ticket` (`wait`/`try_wait`/`cancel`);
//! - [`worker`] — [`WorkerNode`] (`photon worker --connect ADDR`): a
//!   map worker that authenticates with `WorkerHello`, adopts the
//!   coordinator's signature seed, ingests forwarded partition rows
//!   against its own embedded engine and pushes mergeable FD/sketch
//!   summaries back for the coordinator's tree reduction (see
//!   [`crate::coordinator::cluster`]);
//! - [`grpc`] — stub documenting the future tonic/prost swap (cargo
//!   feature `grpc`, mirroring the `xla` gate).
//!
//! The frame grammar and status-code mapping live in
//! [`crate::coordinator::wire`]; tenants in
//! [`crate::coordinator::tenant`]. See docs/architecture.md §"The
//! network front door".

pub mod client;
#[cfg(feature = "grpc")]
pub mod grpc;
pub mod server;
pub mod worker;

pub use client::{ClientError, RemoteTicket, WireClient};
pub use server::WireServer;
pub use worker::{WorkerConfig, WorkerNode};
