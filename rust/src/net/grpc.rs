//! Placeholder for the gRPC transport (cargo feature `grpc`).
//!
//! The hand-written frame codec in [`crate::coordinator::wire`] exists
//! because this build is offline and std-only; its message shapes were
//! deliberately laid out like prost-generated structs (one numbered
//! variant per `oneof` arm, scalar fields in declaration order) so the
//! eventual swap is mechanical:
//!
//! 1. describe the [`Frame`](crate::coordinator::wire::Frame) grammar
//!    as a `photon.v1` proto package (one rpc per client frame, a
//!    server-streamed `Submit` for job results);
//! 2. generate with `tonic-build`; the generated types replace
//!    `WireSpec`/`WireResponse`/`WireStatus` one for one;
//! 3. keep [`StatusCode`](crate::coordinator::wire::StatusCode) as the
//!    `google.rpc.Status.code` domain so typed refusals survive the
//!    transport swap unchanged;
//! 4. the tenant boundary ([`crate::coordinator::tenant`]) moves into a
//!    tonic interceptor reading the token from request metadata.
//!
//! Until tonic/prost are vendored, this module intentionally exports
//! nothing: enabling the feature must compile (CI checks it) but the
//! TCP framing in [`crate::net::server`] remains the only transport.
//! This mirrors how the `xla` feature gates the PJRT runtime arm.

/// Proto package the generated service will land in.
pub const PROTO_PACKAGE: &str = "photon.v1";
