//! [`WorkerNode`]: a map worker for the scale-out plane.
//!
//! `photon worker --connect ADDR --token TOK` dials a coordinator's
//! front door, authenticates with [`Frame::WorkerHello`] (same tenant
//! registry as clients — no anonymous joins) and receives the engine
//! constants every node must share: the signature-operator base seed
//! and the default chunk size. From then on it ingests forwarded
//! partition rows against its own embedded projection engine:
//!
//! - [`Frame::AssignPartition`] opens one merge slot: a contiguous
//!   whole-chunk row range of a stream, with the stream's sizing
//!   (`sketch_m`, `fd_rank`, `range_cap`, declared `total_rows`);
//! - [`Frame::PartitionRows`] buffers rows and flushes full chunks
//!   exactly like the local streaming plane — `S·A` partials at
//!   *absolute* row offsets of the `(total_rows, sketch_m)` signature,
//!   the range pass at the `(cols, range_cap)` signature, one FD insert
//!   per flushed chunk — so a slot's summaries are bit-identical to any
//!   other node computing the same slot;
//! - [`Frame::SealPartition`] flushes tails and pushes one
//!   [`Frame::SlotSummary`] per owned slot (ascending slot order) plus
//!   a [`Frame::PartitionSealed`] FD part, then drops the partition
//!   state and releases its reserved bytes;
//! - [`Frame::FreePartition`] drops the state early (client abort) —
//!   the worker-side `stream_resident_bytes` gauge returns to baseline.
//!
//! A flush failure is reported typed (`StatusCode::ClusterFailed`
//! naming the stream) so the coordinator poisons the stream instead of
//! waiting on a summary that will never come.

use std::collections::BTreeMap;
use std::io::{self, ErrorKind};
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::batcher::{BatchConfig, ProjectionService};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::pool::{DevicePool, PoolConfig};
use crate::coordinator::request::Device;
use crate::coordinator::router::{Availability, Policy, Router};
use crate::coordinator::wire::{
    arm_code, read_frame, read_frame_poll, write_frame, Frame, StatusCode, WireError, WireMat,
    WireStatus, WIRE_VERSION,
};
use crate::linalg::Mat;
use crate::opu::NoiseModel;
use crate::randnla::streaming::FrequentDirections;

/// How long a blocked socket read waits before the worker re-checks its
/// shutdown flag (mirrors the server's poll interval).
const POLL_INTERVAL: Duration = Duration::from_millis(50);

/// Engine knobs for a worker node. The signature-operator seed always
/// comes from the coordinator's `WorkerOk` (all nodes must draw the
/// same operators); everything else defaults to the deterministic host
/// arm so slot summaries are bit-reproducible across nodes.
#[derive(Clone)]
pub struct WorkerConfig {
    /// Batcher config; `seed` is overridden by the coordinator's.
    pub batch: BatchConfig,
    /// Offload policy of the worker's embedded engine.
    pub policy: Policy,
}

impl Default for WorkerConfig {
    fn default() -> Self {
        Self {
            batch: BatchConfig {
                max_cols: 1024,
                max_wait: Duration::from_micros(50),
                noise: NoiseModel::ideal(),
                ..BatchConfig::default()
            },
            policy: Policy::ForceHost,
        }
    }
}

/// One merge slot's ingest state on this worker.
struct Partition {
    r0: usize,
    r1: usize,
    chunk_rows: usize,
    total_rows: usize,
    cols: usize,
    sketch_m: usize,
    range_cap: usize,
    /// Chunk-ordered fold of the slot's `S·A` partials.
    sa: Mat,
    /// The slot's columns of `Yᵀ` (range_cap × (r1−r0)).
    yt: Mat,
    fro2: f64,
    chunks: u64,
    buf: Mat,
    buf_rows: usize,
    /// Next absolute row this slot ingests.
    next: usize,
    arm: Option<Device>,
    mixed_arms: bool,
    y_arm: Option<Device>,
    mixed_y_arms: bool,
    /// Cumulative wall time this slot spent flushing chunks through the
    /// projection plane (µs); rides home on the slot summary so the
    /// coordinator's telemetry plane can stitch worker-side spans.
    ingest_us: u64,
}

impl Partition {
    fn reserved_bytes(&self) -> usize {
        (self.chunk_rows * self.cols
            + self.sketch_m * self.cols
            + self.range_cap * (self.r1 - self.r0))
            * std::mem::size_of::<f64>()
    }
}

/// Per-stream worker state: the owned slots plus one FD sketch fed by
/// every chunk this worker flushes (FD is mergeable, so per-worker
/// sketches reduce at the coordinator).
struct StreamState {
    fd: FrequentDirections,
    fd_rank: usize,
    cols: usize,
    slots: BTreeMap<u64, Partition>,
}

impl StreamState {
    fn reserved_bytes(&self) -> usize {
        2 * self.fd_rank * self.cols * std::mem::size_of::<f64>()
            + self.slots.values().map(Partition::reserved_bytes).sum::<usize>()
    }
}

/// A connected map worker: socket + embedded engine + ingest loop.
pub struct WorkerNode {
    addr: SocketAddr,
    worker_id: u64,
    stop: Arc<AtomicBool>,
    writer: Arc<Mutex<TcpStream>>,
    handle: Option<JoinHandle<()>>,
    metrics: Arc<Metrics>,
}

impl WorkerNode {
    /// Dial `addr`, authenticate with `token`, adopt the coordinator's
    /// engine constants and start ingesting. Returns once the handshake
    /// completed — partition work runs on a background thread.
    pub fn connect(addr: &str, token: &str, cfg: WorkerConfig) -> io::Result<WorkerNode> {
        let mut sock = TcpStream::connect(addr)?;
        sock.set_nodelay(true).ok();
        let hello = Frame::WorkerHello { version: WIRE_VERSION, token: token.to_string() };
        write_frame(&mut sock, 1, &hello).map_err(wire_io)?;
        let (_req, reply) = read_frame(&mut sock).map_err(wire_io)?;
        let (worker_id, seed) = match reply {
            Frame::WorkerOk { worker, seed, .. } => (worker, seed),
            Frame::Status(s) => {
                return Err(io::Error::new(
                    ErrorKind::PermissionDenied,
                    format!("coordinator refused the worker: {}", s.detail),
                ));
            }
            other => {
                return Err(io::Error::new(
                    ErrorKind::InvalidData,
                    format!("expected WorkerOk, got {other:?}"),
                ));
            }
        };
        sock.set_read_timeout(Some(POLL_INTERVAL))?;
        let writer = Arc::new(Mutex::new(sock.try_clone()?));
        let peer = sock.peer_addr()?;

        // The embedded engine: same batcher/router/pool stack as the
        // coordinator's serving plane, seeded with the coordinator's
        // base seed so every node draws identical signature operators.
        let metrics = Arc::new(Metrics::new());
        let batch = BatchConfig { seed, ..cfg.batch };
        let avail = Availability { pjrt: false, ..Availability::default() };
        let router = Router::new(cfg.policy, avail);
        let pool = Arc::new(DevicePool::build(
            &PoolConfig { pjrt_replicas: 0, ..PoolConfig::default() },
            &avail,
        ));
        let (svc, _batcher_join) =
            ProjectionService::start(batch, router, pool, None, metrics.clone(), None);

        let stop = Arc::new(AtomicBool::new(false));
        let handle = {
            let stop = Arc::clone(&stop);
            let writer = Arc::clone(&writer);
            let metrics = Arc::clone(&metrics);
            std::thread::Builder::new().name("worker-ingest".into()).spawn(move || {
                run_loop(sock, &writer, svc, &metrics, &stop);
                drop(_batcher_join);
            })?
        };
        Ok(WorkerNode { addr: peer, worker_id, stop, writer, handle: Some(handle), metrics })
    }

    /// The coordinator address this worker serves.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The id the coordinator registered this worker under.
    pub fn worker_id(&self) -> u64 {
        self.worker_id
    }

    /// The worker's own engine metrics (`stream_resident_bytes`,
    /// `stream_chunks`, …) — the regression tests' source of truth for
    /// "worker-side bytes returned to baseline".
    pub fn metrics(&self) -> Arc<Metrics> {
        Arc::clone(&self.metrics)
    }

    /// Leave the cluster: best-effort `Goodbye`, stop the ingest loop,
    /// join the thread. The coordinator sees the disconnect and poisons
    /// any streams still holding this worker's slots.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        {
            let mut w = self.writer.lock().unwrap();
            let _ = write_frame(&mut *w, 0, &Frame::Goodbye);
            let _ = w.shutdown(std::net::Shutdown::Both);
        }
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for WorkerNode {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

fn wire_io(e: WireError) -> io::Error {
    io::Error::new(ErrorKind::InvalidData, e.to_string())
}

fn send(writer: &Mutex<TcpStream>, frame: &Frame) -> bool {
    let mut w = writer.lock().unwrap();
    write_frame(&mut *w, 0, frame).is_ok()
}

fn run_loop(
    mut rd: TcpStream,
    writer: &Arc<Mutex<TcpStream>>,
    svc: ProjectionService,
    metrics: &Arc<Metrics>,
    stop: &AtomicBool,
) {
    let mut streams: BTreeMap<u64, StreamState> = BTreeMap::new();
    while !stop.load(Ordering::SeqCst) {
        let frame = match read_frame_poll(&mut rd, stop) {
            Ok(None) => continue,
            Ok(Some((_req, f))) => f,
            Err(_) => break,
        };
        match frame {
            Frame::AssignPartition {
                stream,
                epoch: _,
                slot,
                r0,
                r1,
                total_rows,
                cols,
                chunk_rows,
                sketch_m,
                fd_rank,
                range_cap,
            } => {
                let st = streams.entry(stream).or_insert_with(|| StreamState {
                    fd: FrequentDirections::new((fd_rank as usize).max(1), (cols as usize).max(1)),
                    fd_rank: fd_rank as usize,
                    cols: cols as usize,
                    slots: BTreeMap::new(),
                });
                let (r0, r1) = (r0 as usize, r1 as usize);
                let cols = cols as usize;
                let chunk = (chunk_rows as usize).max(1).min(r1.saturating_sub(r0).max(1));
                let p = Partition {
                    r0,
                    r1,
                    chunk_rows: chunk,
                    total_rows: total_rows as usize,
                    cols,
                    sketch_m: sketch_m as usize,
                    range_cap: range_cap as usize,
                    sa: Mat::zeros(sketch_m as usize, cols),
                    yt: Mat::zeros(range_cap as usize, r1 - r0),
                    fro2: 0.0,
                    chunks: 0,
                    buf: Mat::zeros(chunk, cols),
                    buf_rows: 0,
                    next: r0,
                    arm: None,
                    mixed_arms: false,
                    y_arm: None,
                    mixed_y_arms: false,
                    ingest_us: 0,
                };
                let bytes = p.reserved_bytes() as u64;
                st.slots.insert(slot, p);
                // FD buffer counts once per stream; charge it with the
                // first slot so the gauge mirrors what is allocated.
                let fd_bytes = if st.slots.len() == 1 {
                    (2 * st.fd_rank * st.cols * std::mem::size_of::<f64>()) as u64
                } else {
                    0
                };
                metrics.stream_resident_bytes.fetch_add(bytes + fd_bytes, Ordering::Relaxed);
            }
            Frame::PartitionRows { stream, slot, rows } => {
                let Some(st) = streams.get_mut(&stream) else { continue };
                let block = match rows.to_mat() {
                    Ok(m) => m,
                    Err(e) => {
                        fail_stream(&mut streams, stream, metrics, writer, &e.to_string());
                        continue;
                    }
                };
                let Some(p) = st.slots.get_mut(&slot) else { continue };
                let mut at = 0usize;
                let mut err: Option<String> = None;
                while at < block.rows {
                    let take = (p.chunk_rows - p.buf_rows).min(block.rows - at);
                    for i in 0..take {
                        p.buf.row_mut(p.buf_rows + i).copy_from_slice(block.row(at + i));
                    }
                    p.buf_rows += take;
                    at += take;
                    if p.buf_rows == p.chunk_rows {
                        if let Err(e) = flush(p, &mut st.fd, &svc, metrics) {
                            err = Some(e);
                            break;
                        }
                    }
                }
                if let Some(e) = err {
                    fail_stream(&mut streams, stream, metrics, writer, &e);
                }
            }
            Frame::SealPartition { stream, epoch } => {
                let Some(mut st) = streams.remove(&stream) else { continue };
                let seal_clock = Instant::now();
                let mut failed: Option<String> = None;
                // Flush tails and push summaries in ascending slot
                // order (the canonical order the reduction folds in).
                for (slot, p) in st.slots.iter_mut() {
                    if p.buf_rows > 0 {
                        if let Err(e) = flush(p, &mut st.fd, &svc, metrics) {
                            failed = Some(e);
                            break;
                        }
                    }
                    let summary = Frame::SlotSummary {
                        stream,
                        slot: *slot,
                        r0: p.r0 as u64,
                        r1: p.r1 as u64,
                        chunks: p.chunks,
                        fro2: p.fro2.to_bits(),
                        arm: arm_code(if p.mixed_arms { None } else { p.arm }),
                        y_arm: arm_code(if p.mixed_y_arms { None } else { p.y_arm }),
                        sa: WireMat::from_mat(&p.sa),
                        yt: WireMat::from_mat(&p.yt),
                        ingest_us: p.ingest_us,
                    };
                    if !send(writer, &summary) {
                        failed = Some("summary push failed".into());
                        break;
                    }
                }
                let released = st.reserved_bytes() as u64;
                if let Some(e) = failed {
                    metrics.stream_resident_bytes.fetch_sub(released, Ordering::Relaxed);
                    report_failure(writer, stream, &e);
                    continue;
                }
                st.fd.compress();
                let sealed = Frame::PartitionSealed {
                    stream,
                    epoch,
                    fd_bound: st.fd.bound().to_bits(),
                    fd: WireMat::from_mat(&st.fd.sketch()),
                    seal_us: seal_clock.elapsed().as_micros() as u64,
                };
                send(writer, &sealed);
                metrics.stream_resident_bytes.fetch_sub(released, Ordering::Relaxed);
            }
            Frame::FreePartition { stream } => {
                if let Some(st) = streams.remove(&stream) {
                    metrics
                        .stream_resident_bytes
                        .fetch_sub(st.reserved_bytes() as u64, Ordering::Relaxed);
                }
                send(writer, &Frame::PartitionFreed { stream });
            }
            Frame::ShuttingDown | Frame::Goodbye => break,
            _ => {}
        }
    }
}

/// One chunk through the worker's projection plane — the same two
/// batches the local streaming plane runs per chunk, at the same
/// absolute offsets, folded into the slot summaries in chunk order.
fn flush(
    p: &mut Partition,
    fd: &mut FrequentDirections,
    svc: &ProjectionService,
    metrics: &Arc<Metrics>,
) -> Result<(), String> {
    let clock = Instant::now();
    let take = p.buf_rows;
    let r0 = p.next;
    let chunk = Arc::new(p.buf.crop(take, p.cols));
    let run = (|| -> anyhow::Result<()> {
        let p_sa = svc.project_rows_async(chunk.clone(), p.sketch_m, p.total_rows, r0)?;
        let p_y = svc.project_async(chunk.transpose(), p.range_cap)?;
        let ra = p_sa.wait()?;
        let ry = p_y.wait()?;
        let off = r0 - p.r0;
        for i in 0..p.range_cap {
            p.yt.row_mut(i)[off..off + take].copy_from_slice(ry.result.row(i));
        }
        for (dst, v) in p.sa.data.iter_mut().zip(&ra.result.data) {
            *dst += v;
        }
        match p.arm {
            None => p.arm = Some(ra.planned),
            Some(a) if a != ra.planned => p.mixed_arms = true,
            _ => {}
        }
        match p.y_arm {
            None => p.y_arm = Some(ry.planned),
            Some(a) if a != ry.planned => p.mixed_y_arms = true,
            _ => {}
        }
        Ok(())
    })();
    run.map_err(|e| e.to_string())?;
    p.fro2 += chunk.data.iter().map(|v| v * v).sum::<f64>();
    fd.insert(&chunk);
    p.next += take;
    p.buf_rows = 0;
    p.chunks += 1;
    p.ingest_us += clock.elapsed().as_micros() as u64;
    metrics.stream_chunks.fetch_add(1, Ordering::Relaxed);
    Ok(())
}

/// Drop a failed stream's state, release its gauge bytes and tell the
/// coordinator which stream broke (it poisons the deferred slot typed).
fn fail_stream(
    streams: &mut BTreeMap<u64, StreamState>,
    stream: u64,
    metrics: &Arc<Metrics>,
    writer: &Arc<Mutex<TcpStream>>,
    detail: &str,
) {
    if let Some(st) = streams.remove(&stream) {
        metrics.stream_resident_bytes.fetch_sub(st.reserved_bytes() as u64, Ordering::Relaxed);
    }
    report_failure(writer, stream, detail);
}

fn report_failure(writer: &Arc<Mutex<TcpStream>>, stream: u64, detail: &str) {
    let mut status = WireStatus::with_detail(StatusCode::ClusterFailed, detail);
    status.a = stream;
    send(writer, &Frame::Status(status));
}
