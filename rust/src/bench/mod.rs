//! Micro-benchmark harness (no criterion in the offline image).
//!
//! Adaptive warmup + timed runs, robust summary (mean / p50 / p99), and a
//! plain-text + CSV reporter shared by all `cargo bench` targets.

use std::time::{Duration, Instant};

use crate::stats::{percentile, Running};

/// One benchmark's timing summary.
#[derive(Clone, Debug)]
pub struct Summary {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
}

impl Summary {
    /// Flat summary for single-shot measurements (externally timed, one
    /// ns/op value stands in for every percentile) — the shape the
    /// end-to-end benches report.
    pub fn flat(name: String, iters: u64, ns_per_op: f64) -> Self {
        Self {
            name,
            iters,
            mean_ns: ns_per_op,
            p50_ns: ns_per_op,
            p99_ns: ns_per_op,
            min_ns: ns_per_op,
            max_ns: ns_per_op,
        }
    }

    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }

    /// One CSV row: name,iters,mean_ns,p50_ns,p99_ns,min_ns,max_ns.
    pub fn csv_row(&self) -> String {
        format!(
            "{},{},{:.1},{:.1},{:.1},{:.1},{:.1}",
            self.name, self.iters, self.mean_ns, self.p50_ns, self.p99_ns, self.min_ns,
            self.max_ns
        )
    }
}

/// Benchmark configuration.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    pub warmup: Duration,
    pub measure: Duration,
    pub min_iters: u64,
    pub max_iters: u64,
}

impl Default for Config {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(200),
            measure: Duration::from_millis(800),
            min_iters: 5,
            max_iters: 100_000,
        }
    }
}

impl Config {
    /// Smaller budget for expensive end-to-end benches.
    pub fn quick() -> Self {
        Self {
            warmup: Duration::from_millis(50),
            measure: Duration::from_millis(300),
            min_iters: 3,
            max_iters: 10_000,
        }
    }
}

/// Time `f` under `cfg`; `f` must perform one full operation per call.
pub fn run<F: FnMut()>(name: &str, cfg: Config, mut f: F) -> Summary {
    // Warmup.
    let w0 = Instant::now();
    while w0.elapsed() < cfg.warmup {
        f();
    }
    // Measure.
    let mut samples: Vec<f64> = Vec::new();
    let mut stats = Running::new();
    let m0 = Instant::now();
    while (m0.elapsed() < cfg.measure || samples.len() < cfg.min_iters as usize)
        && samples.len() < cfg.max_iters as usize
    {
        let t = Instant::now();
        f();
        let ns = t.elapsed().as_nanos() as f64;
        samples.push(ns);
        stats.push(ns);
    }
    Summary {
        name: name.to_string(),
        iters: samples.len() as u64,
        mean_ns: stats.mean(),
        p50_ns: percentile(&mut samples.clone(), 50.0),
        p99_ns: percentile(&mut samples, 99.0),
        min_ns: stats.min(),
        max_ns: stats.max(),
    }
}

/// Pretty-print a set of summaries as an aligned table.
pub fn report(title: &str, rows: &[Summary]) {
    println!("\n== {title} ==");
    println!(
        "{:<44} {:>8} {:>12} {:>12} {:>12}",
        "benchmark", "iters", "mean", "p50", "p99"
    );
    for r in rows {
        println!(
            "{:<44} {:>8} {:>12} {:>12} {:>12}",
            r.name,
            r.iters,
            fmt_ns(r.mean_ns),
            fmt_ns(r.p50_ns),
            fmt_ns(r.p99_ns)
        );
    }
}

/// True when the bench binary was invoked with `--quick` (CI smoke
/// mode): callers swap in [`Config::quick`] budgets and relaxed gates.
pub fn quick_mode() -> bool {
    std::env::args().any(|a| a == "--quick")
}

/// Write summaries as machine-readable JSON (`[{"name", "iters",
/// "ns_per_op"}...]`) so the perf trajectory is trackable across PRs
/// (BENCH_<target>.json next to the working directory).
pub fn write_json(path: &str, rows: &[Summary]) -> std::io::Result<()> {
    let mut out = String::from("[\n");
    for (i, r) in rows.iter().enumerate() {
        let name = r.name.replace('\\', "\\\\").replace('"', "\\\"");
        out.push_str(&format!(
            "  {{\"name\": \"{}\", \"iters\": {}, \"ns_per_op\": {:.1}}}{}\n",
            name,
            r.iters,
            r.mean_ns,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    out.push_str("]\n");
    std::fs::write(path, out)?;
    println!("(wrote {path})");
    Ok(())
}

/// One acceptance-gate verdict a bench target grades itself against
/// (e.g. "f32 packed projection >= 2x f64"). Gates ride the same
/// `BENCH_<target>.json` artifact as the timings, so CI smoke and the
/// cross-PR trajectory see pass/fail next to the numbers they gate.
#[derive(Clone, Debug)]
pub struct Gate {
    pub name: String,
    pub passed: bool,
    /// Human-readable measurement behind the verdict
    /// (e.g. "speedup 2.31x (need >= 2.0)").
    pub detail: String,
}

impl Gate {
    pub fn new(name: impl Into<String>, passed: bool, detail: impl Into<String>) -> Self {
        Self { name: name.into(), passed, detail: detail.into() }
    }
}

fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

/// Write one bench target's machine-readable artifact,
/// `BENCH_<bench>.json`, in the schema every target shares:
/// `{"bench", "cases": [{"name", "iters", "ns_per_op"}],
/// "gates": [{"name", "passed", "detail"}]}`. This is the single
/// emission path for all `cargo bench` targets (the flat
/// [`write_json`] array remains for ad-hoc dumps).
pub fn emit_json(bench: &str, rows: &[Summary], gates: &[Gate]) -> std::io::Result<()> {
    let mut out = String::new();
    out.push_str(&format!("{{\n  \"bench\": \"{}\",\n", json_escape(bench)));
    out.push_str("  \"cases\": [\n");
    for (i, r) in rows.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"name\": \"{}\", \"iters\": {}, \"ns_per_op\": {:.1}}}{}\n",
            json_escape(&r.name),
            r.iters,
            r.mean_ns,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    out.push_str("  ],\n  \"gates\": [\n");
    for (i, g) in gates.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"name\": \"{}\", \"passed\": {}, \"detail\": \"{}\"}}{}\n",
            json_escape(&g.name),
            g.passed,
            json_escape(&g.detail),
            if i + 1 < gates.len() { "," } else { "" }
        ));
    }
    out.push_str("  ]\n}\n");
    let path = format!("BENCH_{bench}.json");
    std::fs::write(&path, out)?;
    println!("(wrote {path})");
    Ok(())
}

/// Standard bench-target epilogue: emit the shared-schema JSON, print
/// every gate verdict, and exit nonzero if any gate failed — what turns
/// a `cargo bench` target into a CI smoke check.
pub fn finish(bench: &str, rows: &[Summary], gates: &[Gate]) {
    if let Err(e) = emit_json(bench, rows, gates) {
        eprintln!("(could not write BENCH_{bench}.json: {e})");
    }
    let mut failed = false;
    for g in gates {
        let verdict = if g.passed { "PASS" } else { "FAIL" };
        println!("gate {verdict}: {} — {}", g.name, g.detail);
        failed |= !g.passed;
    }
    if failed {
        std::process::exit(1);
    }
}

/// Human-format nanoseconds.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let s = run(
            "spin",
            Config {
                warmup: Duration::from_millis(1),
                measure: Duration::from_millis(10),
                min_iters: 3,
                max_iters: 1000,
            },
            || {
                std::hint::black_box((0..1000).sum::<u64>());
            },
        );
        assert!(s.iters >= 3);
        assert!(s.mean_ns > 0.0);
        assert!(s.p50_ns <= s.p99_ns + 1.0);
        assert!(s.min_ns <= s.mean_ns && s.mean_ns <= s.max_ns);
    }

    #[test]
    fn csv_has_seven_fields() {
        let s = Summary {
            name: "x".into(),
            iters: 1,
            mean_ns: 1.0,
            p50_ns: 1.0,
            p99_ns: 1.0,
            min_ns: 1.0,
            max_ns: 1.0,
        };
        assert_eq!(s.csv_row().split(',').count(), 7);
    }

    #[test]
    fn json_is_well_formed_and_escaped() {
        let rows = vec![Summary {
            name: "matmul \"512^3\"".into(),
            iters: 2,
            mean_ns: 1.5,
            p50_ns: 1.0,
            p99_ns: 2.0,
            min_ns: 1.0,
            max_ns: 2.0,
        }];
        let path = std::env::temp_dir().join("photon_bench_json_test.json");
        let path = path.to_str().unwrap().to_string();
        write_json(&path, &rows).unwrap();
        let s = std::fs::read_to_string(&path).unwrap();
        assert!(s.trim_start().starts_with('['));
        assert!(s.trim_end().ends_with(']'));
        assert!(s.contains("\\\"512^3\\\""), "{s}");
        assert!(s.contains("\"ns_per_op\": 1.5"), "{s}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn emit_json_carries_cases_and_gate_verdicts() {
        // emit_json writes BENCH_<name>.json into the working directory
        // by construction (CI uploads from there); a selftest-named file
        // keeps this test from colliding with real bench artifacts.
        let bench = "harness_selftest";
        let rows = vec![Summary::flat("case \"a\"".into(), 3, 2.5)];
        let gates = vec![
            Gate::new("speedup", true, "2.3x (need >= 2.0)"),
            Gate::new("accuracy", false, "rms 0.5 \"bad\""),
        ];
        emit_json(bench, &rows, &gates).unwrap();
        let path = format!("BENCH_{bench}.json");
        let s = std::fs::read_to_string(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        assert!(s.contains("\"bench\": \"harness_selftest\""), "{s}");
        assert!(s.contains("\\\"a\\\""), "case names must be escaped: {s}");
        assert!(s.contains("\"ns_per_op\": 2.5"), "{s}");
        assert!(s.contains("\"passed\": true"), "{s}");
        assert!(s.contains("\"passed\": false"), "{s}");
        assert!(s.contains("\\\"bad\\\""), "gate details must be escaped: {s}");
        // Braces/brackets balance — cheap well-formedness check without
        // a JSON parser in the image.
        let opens = s.matches('{').count() + s.matches('[').count();
        let closes = s.matches('}').count() + s.matches(']').count();
        assert_eq!(opens, closes, "{s}");
    }

    #[test]
    fn fmt_units() {
        assert!(fmt_ns(500.0).ends_with("ns"));
        assert!(fmt_ns(5_000.0).ends_with("µs"));
        assert!(fmt_ns(5_000_000.0).ends_with("ms"));
        assert!(fmt_ns(5e9).ends_with('s'));
    }
}
