//! Bench: Fig. 2 — projection time vs dimension (measured + modeled).
//!
//! ```bash
//! cargo bench --bench fig2_projection
//! ```
//!
//! Series printed:
//!   host-gemm   measured rust blocked GEMM projection (digital baseline)
//!   pjrt        measured AOT proj_xla artifact execution (GPU-arm stand-in)
//!   opu-sim     measured wall-clock of the full OPU simulation (for
//!               reference only — the *simulator* is software)
//!   model-*     the paper-constant models the router actually uses
//! plus the crossover/OOM headline numbers.
//!
//! Emits BENCH_fig2_projection.json (shared bench schema; no gates —
//! the measured series is descriptive, the modeled headline is pinned
//! by unit tests in reports::fig2).

use photonic_randnla::bench::{finish, fmt_ns, run, Config};
use photonic_randnla::linalg::{matmul, Mat};
use photonic_randnla::opu::{NoiseModel, OpuConfig, OpuDevice};
use photonic_randnla::reports::fig2;
use photonic_randnla::rng::Xoshiro256;
use photonic_randnla::runtime::PjrtEngine;

fn main() {
    let mut rows = Vec::new();
    let mut rng = Xoshiro256::new(1);
    let quick = Config::quick();

    // Measured: host GEMM projection at a ladder of square sizes.
    for n in [256usize, 512, 1024] {
        let m = n / 2;
        let r = Mat::gaussian(m, n, 1.0, &mut rng);
        let a = Mat::gaussian(n, n, 1.0, &mut rng);
        rows.push(run(&format!("host-gemm n={n}"), quick, || {
            std::hint::black_box(matmul(&r, &a));
        }));
    }

    // Measured: PJRT artifact execution (requires `make artifacts`).
    match PjrtEngine::start_default() {
        Ok(engine) => {
            let h = engine.handle();
            for (m, n) in h.buckets("proj_xla").unwrap_or_default() {
                if m != n / 2 {
                    continue;
                }
                let r = Mat::gaussian(m, n, 1.0, &mut rng);
                let a = Mat::gaussian(n, n, 1.0, &mut rng);
                let _ = h.project("proj_xla", r.clone(), a.clone()); // compile
                let hh = h.clone();
                rows.push(run(&format!("pjrt proj_xla n={n}"), quick, move || {
                    std::hint::black_box(
                        hh.project("proj_xla", r.clone(), a.clone()).unwrap(),
                    );
                }));
            }
        }
        Err(e) => eprintln!("(pjrt series skipped: {e})"),
    }

    // Measured: full OPU simulation wall-clock (one 8-bit linear project).
    for n in [256usize, 512] {
        let m = n / 2;
        let dev = OpuDevice::new(
            OpuConfig::new(7, m, n).with_noise(NoiseModel::realistic()),
        );
        let x = Mat::gaussian(n, 8, 1.0, &mut rng);
        rows.push(run(&format!("opu-sim n={n} k=8"), quick, || {
            std::hint::black_box(dev.project(&x));
        }));
    }

    photonic_randnla::bench::report("Fig. 2 measured series", &rows);

    // Modeled series + headline (the actual figure).
    let cfg = fig2::Fig2Config::default();
    let model = fig2::model_rows(&cfg);
    println!("\nmodel series (ms):");
    println!("{:>10} {:>14} {:>14}", "n", "model-opu", "model-gpu");
    for n in &cfg.model_dims {
        let opu = model
            .iter()
            .find(|r| r.arm == "model-opu" && r.x == *n as f64)
            .unwrap();
        let gpu = model
            .iter()
            .find(|r| r.arm == "model-gpu" && r.x == *n as f64)
            .unwrap();
        let gpu_s = if gpu.y.is_nan() { "OOM".to_string() } else { format!("{:.3}", gpu.y) };
        println!("{:>10} {:>14.3} {:>14}", n, opu.y, gpu_s);
    }
    let h = fig2::headline();
    println!(
        "\ncrossover n ~ {} (paper ~1.2e4) | GPU OOM n ~ {} (paper ~7e4) | \
         OPU @1e6 {:.2} ms (paper ~1.2)",
        h.crossover_dim, h.gpu_oom_dim, h.opu_ms_at_1m
    );

    println!("\nCSV");
    println!("name,iters,mean_ns,p50_ns,p99_ns,min_ns,max_ns");
    for r in &rows {
        println!("{}", r.csv_row());
    }
    println!(
        "\nfastest measured digital projection: {}",
        fmt_ns(rows.iter().map(|r| r.mean_ns).fold(f64::INFINITY, f64::min))
    );
    finish("fig2_projection", &rows, &[]);
}
