//! Bench: structured sketch operators vs the dense Gaussian host sketch.
//!
//! ```bash
//! cargo bench --bench sketch_ops            # full budgets, 5x gate
//! cargo bench --bench sketch_ops -- --quick # CI smoke, 3x gate
//! ```
//!
//! The tentpole's acceptance shape (paper Fig. 2 scale for the host
//! arm): at n=4096, m=512, k=16 both structured operators must project
//! >= 5x faster than the dense Gaussian host sketch, while matching the
//! dense path's JL scale (`E[S^T S] = m I`) closely enough that every
//! estimator keeps its tolerances — the scale sanity check runs inline
//! here, the statistical suite lives in tests/prop_sketch_stats.rs.
//!
//! Emits BENCH_sketch_ops.json (shared bench schema: cases + gates) for
//! cross-PR tracking and exits non-zero when a gate fails.

use photonic_randnla::bench::{finish, quick_mode, report, run, Config, Gate};
use photonic_randnla::linalg::Mat;
use photonic_randnla::randnla::backend::{DigitalSketcher, Sketcher};
use photonic_randnla::randnla::structured::{SparseSignSketcher, SrhtSketcher};
use photonic_randnla::rng::Xoshiro256;

const N: usize = 4096;
const M: usize = 512;
const K: usize = 16;
const SPARSE_NNZ: usize = 8;

fn main() {
    let quick = quick_mode();
    let cfg = if quick {
        Config {
            warmup: std::time::Duration::from_millis(20),
            measure: std::time::Duration::from_millis(150),
            min_iters: 3,
            max_iters: 1000,
        }
    } else {
        Config::quick() // dense 512x4096x16 GEMMs: keep budgets moderate
    };

    let mut rng = Xoshiro256::new(42);
    let x = Mat::gaussian(N, K, 1.0, &mut rng);

    // Operators are built once; the bench times the projection (the
    // serving-path hot loop), not operator setup.
    let dense = DigitalSketcher::new(M, N, 7);
    let srht = SrhtSketcher::new(M, N, 7);
    let sparse = SparseSignSketcher::new(M, N, SPARSE_NNZ, 7);

    let mut rows = Vec::new();
    let dense_row = run(&format!("dense gaussian {M}x{N} k={K}"), cfg, || {
        std::hint::black_box(dense.project(&x));
    });
    let srht_row = run(&format!("srht {M}x{N} k={K}"), cfg, || {
        std::hint::black_box(srht.project(&x));
    });
    let sparse_row = run(&format!("sparse-sign s={SPARSE_NNZ} {M}x{N} k={K}"), cfg, || {
        std::hint::black_box(sparse.project(&x));
    });

    // Operator-construction cost, for the amortisation story.
    rows.push(run("build srht operator", cfg, || {
        std::hint::black_box(SrhtSketcher::new(M, N, 9));
    }));
    rows.push(run("build sparse-sign operator", cfg, || {
        std::hint::black_box(SparseSignSketcher::new(M, N, SPARSE_NNZ, 9));
    }));

    let (dense_ns, srht_ns, sparse_ns) =
        (dense_row.mean_ns, srht_row.mean_ns, sparse_row.mean_ns);
    rows.insert(0, sparse_row);
    rows.insert(0, srht_row);
    rows.insert(0, dense_row);

    report("sketch operators", &rows);

    // JL-scale sanity: the structured sketches must sit on the same
    // E||Sx||^2 = m ||x||^2 convention the estimators divide by.
    let x1 = Mat::gaussian(N, 1, 1.0, &mut rng);
    let x2: f64 = x1.data.iter().map(|v| v * v).sum();
    for (label, y) in [("srht", srht.project(&x1)), ("sparse", sparse.project(&x1))] {
        let ratio = y.data.iter().map(|v| v * v).sum::<f64>() / (M as f64 * x2);
        assert!(
            (ratio - 1.0).abs() < 0.5,
            "{label} sketch scale off: ||Sx||^2/(m||x||^2) = {ratio}"
        );
    }

    let srht_speedup = dense_ns / srht_ns;
    let sparse_speedup = dense_ns / sparse_ns;
    let floor = if quick { 3.0 } else { 5.0 };
    println!(
        "\nstructured speedup over dense at n={N} m={M} k={K}: \
         srht {srht_speedup:.1}x, sparse {sparse_speedup:.1}x (gate >= {floor}x)"
    );
    let gates = vec![
        Gate::new(
            "srht speedup over dense",
            srht_speedup >= floor,
            format!("{srht_speedup:.1}x (need >= {floor}x)"),
        ),
        Gate::new(
            "sparse-sign speedup over dense",
            sparse_speedup >= floor,
            format!("{sparse_speedup:.1}x (need >= {floor}x)"),
        ),
    ];
    finish("sketch_ops", &rows, &gates);
}
