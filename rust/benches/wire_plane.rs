//! Bench: the network front door — loopback remote submits vs the
//! in-process session API.
//!
//! ```bash
//! cargo bench --bench wire_plane [-- --quick]
//! ```
//!
//! Two engines with identical configs serve the same handle-based
//! projection workload (one n x 64 operand, k pipelined jobs per rep):
//!
//! - **in-process** — `submit_spec` against an embedded coordinator
//!   (the client_plane handle path, end to end: submit + wait);
//! - **remote** — the same submissions through `WireClient` over a
//!   loopback TCP connection to a `WireServer` fronting the second
//!   engine (frame encode + syscall + decode + waiter round trip).
//!
//! Both paths force the host arm with ideal noise, so the seeded
//! operator draws match and results must agree bitwise across the wire.
//!
//! Acceptance gates: remote end-to-end throughput >= 0.5x in-process
//! (0.3x in --quick smoke mode), and the p50 per-job wire overhead
//! (sequential remote p50 minus in-process p50) <= 1 ms. Emits
//! BENCH_wire_plane.json.

use std::time::Instant;

use photonic_randnla::bench::{self, Gate, Summary};
use photonic_randnla::coordinator::{
    BatchConfig, Coordinator, CoordinatorConfig, JobSpec, OperandId, OperandRef, Policy,
    PoolConfig, QosClass, SubmitOptions, TenantRegistry,
};
use photonic_randnla::linalg::Mat;
use photonic_randnla::net::{WireClient, WireServer};
use photonic_randnla::opu::NoiseModel;
use photonic_randnla::rng::Xoshiro256;
use photonic_randnla::stats;
use photonic_randnla::testkit::ephemeral_loopback;

fn coordinator() -> Coordinator {
    Coordinator::start(CoordinatorConfig {
        workers: 4,
        policy: Policy::ForceHost,
        batch: BatchConfig {
            max_wait: std::time::Duration::from_micros(50),
            noise: NoiseModel::ideal(),
            ..Default::default()
        },
        pool: PoolConfig { pjrt_replicas: 0, ..Default::default() },
        ..Default::default()
    })
    .expect("coordinator start")
}

fn spec(id: OperandId, m: usize) -> JobSpec {
    JobSpec::Projection { data: OperandRef::Handle(id), m }
}

fn main() {
    let quick = bench::quick_mode();
    let n = if quick { 512 } else { 2048 };
    let cols = 64usize;
    let m = 16usize;
    let k = if quick { 16u64 } else { 32 };
    let reps = if quick { 3 } else { 5 };
    let singles = if quick { 20 } else { 60 };
    let mib = (n * cols * 8) as f64 / (1024.0 * 1024.0);

    println!(
        "== wire plane: {k} pipelined jobs on one {n} x {cols} operand ({mib:.1} MiB), m = {m} =="
    );

    let mut rng = Xoshiro256::new(1);
    let x = Mat::gaussian(n, cols, 1.0, &mut rng);

    // ---- in-process baseline --------------------------------------
    let local = coordinator();
    let id = local.upload(x.clone()).expect("upload");
    let mut local_best = f64::INFINITY;
    let mut local_result: Option<Mat> = None;
    for _ in 0..reps {
        let t0 = Instant::now();
        let tickets: Vec<_> = (0..k)
            .map(|_| local.submit_spec(spec(id, m), SubmitOptions::default()).expect("submit"))
            .collect();
        for t in tickets {
            let r = t.wait().expect("local job");
            local_result.get_or_insert_with(|| r.payload.matrix().unwrap().clone());
        }
        let dt = t0.elapsed().as_nanos() as f64;
        local_best = local_best.min(dt / k as f64);
    }
    let mut local_lat: Vec<f64> = Vec::with_capacity(singles);
    for _ in 0..singles {
        let t0 = Instant::now();
        local.run_spec(spec(id, m), SubmitOptions::default()).expect("local single");
        local_lat.push(t0.elapsed().as_nanos() as f64);
    }
    local.shutdown();

    // ---- remote over loopback -------------------------------------
    let tenants =
        TenantRegistry::new().add("bench", "bench-token", usize::MAX, QosClass::Interactive);
    let server =
        WireServer::start(coordinator(), &ephemeral_loopback(), tenants).expect("server start");
    let client =
        WireClient::connect(server.addr(), "bench-token").expect("client connect");
    let rid = client.upload(&x).expect("remote upload");
    let mut remote_best = f64::INFINITY;
    let mut remote_result: Option<Mat> = None;
    for _ in 0..reps {
        let t0 = Instant::now();
        let tickets: Vec<_> = (0..k)
            .map(|_| client.submit(&spec(rid, m), SubmitOptions::default()).expect("submit"))
            .collect();
        for t in tickets {
            let r = t.wait().expect("remote job");
            remote_result.get_or_insert_with(|| r.payload.matrix().unwrap().clone());
        }
        let dt = t0.elapsed().as_nanos() as f64;
        remote_best = remote_best.min(dt / k as f64);
    }
    let mut remote_lat: Vec<f64> = Vec::with_capacity(singles);
    for _ in 0..singles {
        let t0 = Instant::now();
        client.run(&spec(rid, m), SubmitOptions::default()).expect("remote single");
        remote_lat.push(t0.elapsed().as_nanos() as f64);
    }

    // Same seeded operator on both engines: the wire must be lossless.
    assert_eq!(
        local_result.unwrap(),
        remote_result.unwrap(),
        "remote projection diverged bitwise from the in-process result"
    );
    drop(client);
    server.shutdown();

    let rows = vec![
        Summary::flat(format!("in-process e2e n={n} m={m}"), k, local_best),
        Summary::flat(format!("remote e2e n={n} m={m}"), k, remote_best),
    ];
    bench::report("wire plane end-to-end submit+wait", &rows);

    let throughput = local_best / remote_best;
    let local_p50 = stats::percentile(&mut local_lat, 50.0);
    let remote_p50 = stats::percentile(&mut remote_lat, 50.0);
    let overhead_ms = (remote_p50 - local_p50) / 1e6;
    println!(
        "\nheadline: remote throughput {throughput:.2}x in-process, \
         p50 wire overhead {overhead_ms:.3} ms \
         (p50 in-process {:.3} ms, remote {:.3} ms)",
        local_p50 / 1e6,
        remote_p50 / 1e6
    );

    let floor = if quick { 0.3 } else { 0.5 };
    let gates = vec![
        Gate::new(
            "remote throughput vs in-process",
            throughput >= floor,
            format!("{throughput:.2}x (need >= {floor}x)"),
        ),
        Gate::new(
            "p50 wire overhead per job",
            overhead_ms <= 1.0,
            format!("{overhead_ms:.3} ms (need <= 1.000 ms)"),
        ),
    ];
    bench::finish("wire_plane", &rows, &gates);
}
