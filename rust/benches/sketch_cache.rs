//! Bench: the result plane's content-addressed sketch cache under a
//! zipfian repeated-submit workload.
//!
//! ```bash
//! cargo bench --bench sketch_cache [-- --quick]
//! ```
//!
//! The serving-shaped claim behind ISSUE 7: production RandNLA traffic
//! is heavy-tailed — a few hot operands absorb most submissions — so a
//! content-addressed cache in front of the projection plane converts
//! the tail's device passes into O(1) lookups. Two series over the
//! *same* zipf(1.1) trace of Hutchinson-trace jobs on a pool of
//! operands:
//!
//! - **cache on**  — `cache_quota` sized to hold every hot sketch;
//!   first touch of a key computes and parks, repeats serve from the
//!   store without a single batcher flush;
//! - **cache off** — `cache_quota: 0`, the seed behavior: every submit
//!   takes the full projection path.
//!
//! Acceptance gates (ISSUE 7):
//! - hit rate over the zipf trace >= 60%;
//! - served throughput >= 2x the cache-off baseline (1.5x in --quick);
//! - a pure-hit phase executes **zero** device projections, asserted
//!   against the batcher's `projections_executed` counter;
//! - cached results are bit-identical to a `bypass_cache` cold run at
//!   every precision tier (f64 / f32 / bf16).
//!
//! Emits BENCH_sketch_cache.json.

use std::time::Instant;

use photonic_randnla::bench::{self, Gate, Summary};
use photonic_randnla::coordinator::{
    BatchConfig, Coordinator, CoordinatorConfig, JobSpec, OperandId, OperandRef, Policy,
    Precision, SubmitOptions, TraceEstimator,
};
use photonic_randnla::opu::NoiseModel;
use photonic_randnla::rng::Xoshiro256;
use photonic_randnla::workload::psd_matrix;

fn coordinator(cache_quota: usize) -> Coordinator {
    Coordinator::start(CoordinatorConfig {
        workers: 4,
        policy: Policy::ForceHost,
        batch: BatchConfig {
            max_wait: std::time::Duration::from_micros(50),
            noise: NoiseModel::ideal(),
            ..Default::default()
        },
        cache_quota,
        ..Default::default()
    })
    .expect("coordinator start")
}

/// Zipf(s) CDF over ranks 1..=k.
fn zipf_cdf(k: usize, s: f64) -> Vec<f64> {
    let mut w: Vec<f64> = (1..=k).map(|i| 1.0 / (i as f64).powf(s)).collect();
    let total: f64 = w.iter().sum();
    let mut acc = 0.0;
    for v in &mut w {
        acc += *v / total;
        *v = acc;
    }
    w
}

fn zipf_trace(k: usize, s: f64, len: usize, seed: u64) -> Vec<usize> {
    let cdf = zipf_cdf(k, s);
    let mut rng = Xoshiro256::new(seed);
    (0..len)
        .map(|_| {
            let u = rng.next_f64();
            cdf.iter().position(|&c| u < c).unwrap_or(k - 1)
        })
        .collect()
}

fn trace_spec(id: OperandId, m: usize) -> JobSpec {
    JobSpec::Trace { a: OperandRef::Handle(id), m, estimator: TraceEstimator::Hutchinson }
}

/// Submit the whole trace, then drain: served throughput is jobs over
/// the full submit+drain window (what a saturated client observes).
fn run_trace(c: &Coordinator, ids: &[OperandId], trace: &[usize], m: usize) -> (f64, Vec<f64>) {
    let t0 = Instant::now();
    let tickets: Vec<_> = trace
        .iter()
        .map(|&i| c.submit_spec(trace_spec(ids[i], m), SubmitOptions::default()).expect("submit"))
        .collect();
    let scalars: Vec<f64> = tickets
        .into_iter()
        .map(|t| t.wait().expect("trace job").payload.scalar().unwrap())
        .collect();
    let dt = t0.elapsed().as_nanos() as f64;
    (dt / trace.len() as f64, scalars)
}

fn main() {
    let quick = bench::quick_mode();
    let n = if quick { 256 } else { 512 };
    let ops = 16usize; // operand pool (zipf ranks)
    let m = 64usize; // sketch width => two m x n passes per miss
    let submits = if quick { 120 } else { 400 };

    println!(
        "== sketch cache: zipf(1.1) x {submits} trace submits over {ops} {n} x {n} operands, m = {m} =="
    );

    let trace = zipf_trace(ops, 1.1, submits, 42);
    let mats: Vec<_> = (0..ops).map(|i| psd_matrix(n, 64, 1_000 + i as u64)).collect();

    // -- cache on ----------------------------------------------------
    let c = coordinator(64 * 1024 * 1024);
    let ids: Vec<OperandId> = mats.iter().map(|a| c.upload(a.clone()).expect("upload")).collect();
    let (on_ns, on_vals) = run_trace(&c, &ids, &trace, m);
    let hits = c.metrics.cache_hits.load(std::sync::atomic::Ordering::Relaxed);
    let misses = c.metrics.cache_misses.load(std::sync::atomic::Ordering::Relaxed);
    let hit_rate = hits as f64 / (hits + misses) as f64;
    println!(
        "cache on : {:.1}us/job  hits={hits} misses={misses} ({:.0}% hit rate), {} B parked",
        on_ns / 1e3,
        hit_rate * 100.0,
        c.cache().bytes()
    );

    // Pure-hit phase: every key is warm, so the projection counter must
    // not move — the "hits run zero device passes" guarantee, measured
    // at the batcher (ground truth), not inferred from cache counters.
    let proj_before = c.metrics.projections_executed.load(std::sync::atomic::Ordering::Relaxed);
    let hit_phase = if quick { 30 } else { 100 };
    let (_, _) = run_trace(&c, &ids, &trace[..hit_phase.min(trace.len())], m);
    let proj_delta = c.metrics.projections_executed.load(std::sync::atomic::Ordering::Relaxed)
        - proj_before;
    println!("pure-hit phase: {proj_delta} device projections (want 0)");

    // Per-tier bit-identity: cached vs bypass cold path.
    let mut tiers_identical = true;
    for tier in [Precision::F64, Precision::F32, Precision::Bf16] {
        let opts = SubmitOptions::default().with_precision(tier);
        let warm = c.run_spec(trace_spec(ids[0], m), opts).expect("warm").payload;
        let hit = c.run_spec(trace_spec(ids[0], m), opts).expect("hit").payload;
        let cold = c
            .run_spec(trace_spec(ids[0], m), opts.bypass_cache())
            .expect("cold")
            .payload;
        let (w, h, b) = (
            warm.scalar().unwrap().to_bits(),
            hit.scalar().unwrap().to_bits(),
            cold.scalar().unwrap().to_bits(),
        );
        let same = w == h && w == b;
        println!("tier {tier:?}: warm/hit/cold bits identical = {same}");
        tiers_identical &= same;
    }
    c.shutdown();

    // -- cache off (seed behavior) -----------------------------------
    let c0 = coordinator(0);
    let ids0: Vec<OperandId> =
        mats.iter().map(|a| c0.upload(a.clone()).expect("upload")).collect();
    let (off_ns, off_vals) = run_trace(&c0, &ids0, &trace, m);
    println!("cache off: {:.1}us/job", off_ns / 1e3);
    c0.shutdown();

    // Same operands, same operator seeds: the two series must agree
    // bitwise job-for-job, cached or not.
    assert_eq!(on_vals.len(), off_vals.len());
    for (i, (a, b)) in on_vals.iter().zip(&off_vals).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "job {i}: cached series diverged from seed behavior");
    }

    let rows = vec![
        Summary::flat(format!("cache on  zipf(1.1) n={n} m={m}"), submits as u64, on_ns),
        Summary::flat(format!("cache off zipf(1.1) n={n} m={m}"), submits as u64, off_ns),
    ];
    bench::report("sketch cache serving", &rows);

    let speedup = off_ns / on_ns;
    let floor = if quick { 1.5 } else { 2.0 };
    println!("\nheadline: cache-on serves the zipf trace at {speedup:.1}x the cache-off baseline");
    let gates = vec![
        Gate::new(
            "zipf(1.1) hit rate",
            hit_rate >= 0.60,
            format!("{:.0}% (need >= 60%)", hit_rate * 100.0),
        ),
        Gate::new(
            "served throughput over cache-off baseline",
            speedup >= floor,
            format!("{speedup:.1}x (need >= {floor}x)"),
        ),
        Gate::new(
            "pure-hit phase device projections",
            proj_delta == 0,
            format!("{proj_delta} (need 0)"),
        ),
        Gate::new(
            "per-tier bit-identity vs cold path",
            tiers_identical,
            format!("f64/f32/bf16 identical = {tiers_identical}"),
        ),
    ];
    bench::finish("sketch_cache", &rows, &gates);
}
