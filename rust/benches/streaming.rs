//! Bench: the streaming ingestion plane — one-pass randSVD memory
//! footprint vs the resident-operand path.
//!
//! ```bash
//! cargo bench --bench streaming [-- --quick]
//! ```
//!
//! The subsystem's whole claim: a chunked operand is served at a small,
//! fixed fraction of the resident footprint without giving up seeded
//! accuracy. Two runs over the same low-rank-plus-noise target:
//!
//! - **resident** — upload the full n x n operand, run `RandSvd` against
//!   the handle (peak store bytes = the operand);
//! - **streaming** — `begin_stream` / chunked `append` / `seal`, then
//!   the one-pass `RandSvd` over the stream handle (peak resident bytes
//!   = the `stream_resident_bytes` gauge: chunk buffer + summaries).
//!
//! Acceptance gates (hard, both modes):
//! 1. streaming peak resident bytes <= 25% of the resident operand;
//! 2. equal seeded accuracy: streaming reconstruction error within
//!    0.02 absolute of the resident run's.
//!
//! Emits BENCH_streaming.json.

use std::sync::atomic::Ordering;
use std::time::Instant;

use photonic_randnla::bench::{self, Gate, Summary};
use photonic_randnla::coordinator::{
    mat_bytes, BatchConfig, Coordinator, CoordinatorConfig, JobSpec, OperandRef, Policy,
    PoolConfig, StreamOpts, SubmitOptions,
};
use photonic_randnla::linalg::{self, rel_frobenius_error, Mat};
use photonic_randnla::opu::NoiseModel;
use photonic_randnla::perfmodel::{stream_ingest_ms, SketchKind};
use photonic_randnla::rng::Xoshiro256;

fn coordinator(chunk_rows: usize) -> Coordinator {
    Coordinator::start(CoordinatorConfig {
        workers: 4,
        policy: Policy::ForceHost,
        batch: BatchConfig {
            max_wait: std::time::Duration::from_micros(50),
            noise: NoiseModel::ideal(),
            ..Default::default()
        },
        pool: PoolConfig { pjrt_replicas: 0, ..Default::default() },
        stream_chunk_rows: chunk_rows,
        ..Default::default()
    })
    .expect("coordinator start")
}

/// Low-rank-plus-noise target built in O(n^2 * rank) (no dense SVD of an
/// n x n matrix at bench scale).
fn low_rank_target(n: usize, rank: usize, seed: u64) -> Mat {
    let mut rng = Xoshiro256::new(seed);
    let l = Mat::gaussian(n, rank, 1.0, &mut rng);
    let r = Mat::gaussian(rank, n, 1.0, &mut rng);
    let mut a = linalg::matmul(&l, &r).scale(1.0 / (rank as f64).sqrt());
    for v in a.data.iter_mut() {
        *v += 1e-3 * rng.next_normal();
    }
    a
}

fn main() {
    let quick = bench::quick_mode();
    let n = if quick { 1024 } else { 4096 };
    let chunk_rows = if quick { 64 } else { 256 };
    let rank = if quick { 12 } else { 24 };
    let oversample = 8usize;
    let cap = rank + oversample;
    let sketch_m = 4 * cap;
    let fd_rank = 2 * rank;

    let a = low_rank_target(n, rank, 1);
    let operand_bytes = mat_bytes(&a);
    println!(
        "== streaming one-pass randSVD: n={n}, chunk={chunk_rows}, rank={rank} \
         (operand {:.1} MiB) ==",
        operand_bytes as f64 / (1024.0 * 1024.0)
    );

    // ---- resident path --------------------------------------------------
    let c = coordinator(chunk_rows);
    let t0 = Instant::now();
    let id = c.upload(a.clone()).expect("upload");
    let resident_peak = c.store().bytes();
    let resp = c
        .run_spec(
            JobSpec::RandSvd {
                a: OperandRef::Handle(id),
                rank,
                oversample,
                power_iters: 0,
                publish_q: false,
                tol: None,
            },
            SubmitOptions::default(),
        )
        .expect("resident randsvd");
    let resident_ns = t0.elapsed().as_nanos() as f64;
    let (u, s, vt) = resp.payload.svd().expect("svd payload");
    let resident_err = rel_frobenius_error(&a, &linalg::reconstruct(u, s, vt));
    c.free_operand(id);
    c.shutdown();

    // ---- streaming path -------------------------------------------------
    let c = coordinator(chunk_rows);
    let t0 = Instant::now();
    let sid = c
        .begin_stream(
            n,
            n,
            StreamOpts {
                chunk_rows: None,
                sketch_m,
                fd_rank,
                range_cap: cap,
            },
        )
        .expect("begin stream");
    // The stream's lifetime peak IS the gauge right after begin: the
    // footprint is a constant (chunk buffer + summaries) that only
    // shrinks at seal — this is the metric the acceptance gate bounds.
    let open_peak = c.metrics.stream_resident_bytes.load(Ordering::Relaxed) as usize;
    let mut r0 = 0usize;
    while r0 < n {
        let r1 = (r0 + chunk_rows).min(n);
        let piece = Mat::from_fn(r1 - r0, n, |i, j| a.at(r0 + i, j));
        c.append_stream(sid, &piece).expect("append");
        r0 = r1;
    }
    c.seal_stream(sid).expect("seal");
    let ingest_ns = t0.elapsed().as_nanos() as f64;
    let stream_peak = c.store().bytes();
    let stream_gauge = c.metrics.stream_resident_bytes.load(Ordering::Relaxed) as usize;
    let expected_open = (chunk_rows * n + cap * n + sketch_m * n + 2 * fd_rank * n) * 8;
    assert_eq!(open_peak, expected_open, "gauge drifted from the reserve formula");

    let t0 = Instant::now();
    let resp = c
        .run_spec(
            JobSpec::RandSvd {
                a: OperandRef::Stream(sid),
                rank,
                oversample,
                power_iters: 0,
                publish_q: false,
                tol: None,
            },
            SubmitOptions::default(),
        )
        .expect("streaming randsvd");
    let svd_ns = t0.elapsed().as_nanos() as f64;
    let (u, s, vt) = resp.payload.svd().expect("svd payload");
    let stream_err = rel_frobenius_error(&a, &linalg::reconstruct(u, s, vt));
    let chunks = c.metrics.stream_chunks.load(Ordering::Relaxed);
    c.free_stream(sid);
    assert_eq!(c.store().bytes(), 0, "freed stream leaked quota bytes");
    c.shutdown();

    let rows = vec![
        Summary::flat(format!("resident randsvd n={n}"), 1, resident_ns),
        // Per-chunk cost, matching the ns/op convention of every other
        // bench artifact.
        Summary::flat(
            format!("stream ingest n={n} chunk={chunk_rows}"),
            chunks,
            ingest_ns / chunks.max(1) as f64,
        ),
        Summary::flat(format!("stream one-pass svd n={n}"), 1, svd_ns),
    ];
    bench::report("streaming ingestion plane", &rows);

    let predicted = stream_ingest_ms(SketchKind::Dense, n, chunk_rows, sketch_m, n);
    println!(
        "\nfootprint: resident {:.1} MiB | stream open {:.1} MiB (sealed gauge {:.1} MiB, \
         store {:.1} MiB) | {chunks} chunks (perfmodel co-range ingest ~{predicted:.1} ms)",
        resident_peak as f64 / (1024.0 * 1024.0),
        open_peak as f64 / (1024.0 * 1024.0),
        stream_gauge as f64 / (1024.0 * 1024.0),
        stream_peak as f64 / (1024.0 * 1024.0),
    );
    println!("accuracy: resident rel err {resident_err:.2e} | streaming rel err {stream_err:.2e}");

    // Gate 1: the bounded footprint — the open-stream constant (its
    // lifetime peak) must sit at or under a quarter of the operand.
    // Gate 2: equal seeded accuracy.
    let frac = open_peak as f64 / operand_bytes as f64;
    let gates = vec![
        Gate::new(
            "streaming footprint <= 25% of resident",
            frac <= 0.25,
            format!("{:.0}% of the resident operand", frac * 100.0),
        ),
        Gate::new(
            "streaming accuracy within 0.02 of resident",
            stream_err <= resident_err + 0.02,
            format!("stream rel err {stream_err:.3e} vs resident {resident_err:.3e}"),
        ),
    ];
    println!(
        "\nheadline: one-pass streaming randSVD at {:.0}% of the resident footprint",
        frac * 100.0
    );
    bench::finish("streaming", &rows, &gates);
}
