//! Bench: ablations over the design choices DESIGN.md calls out.
//!
//! ```bash
//! cargo bench --bench ablations
//! ```
//!
//! A1  noise chain (ideal / realistic / harsh) x sketch quality   (claim C3)
//! A2  DMD bit depth (2..12) x linear-projection fidelity
//! A3  anchor length x calibration yield + fidelity
//! A4  dynamic batching (max_wait) x service throughput
//!
//! Emits BENCH_ablations.json (shared bench schema; no gates — the
//! ablation grid is exploratory, the hard gates live in the other
//! targets).

use std::sync::Arc;
use std::time::{Duration, Instant};

use photonic_randnla::bench::{self, Summary};
use photonic_randnla::coordinator::{
    BatchConfig, Coordinator, CoordinatorConfig, Job, Policy,
};
use photonic_randnla::linalg::{matmul, rel_frobenius_error, Mat};
use photonic_randnla::opu::{NoiseModel, OpuConfig, OpuDevice};
use photonic_randnla::randnla::{approx_matmul_tn, exact_matmul_tn, OpuSketcher};
use photonic_randnla::rng::Xoshiro256;
use photonic_randnla::stats::Running;
use photonic_randnla::workload::correlated_pair;

fn main() {
    let mut rows = Vec::new();
    ablation_noise(&mut rows);
    ablation_bits(&mut rows);
    ablation_anchor(&mut rows);
    ablation_batching(&mut rows);
    bench::finish("ablations", &rows, &[]);
}

/// A1: the "negligible precision loss" claim, quantified.
fn ablation_noise(rows: &mut Vec<Summary>) {
    println!("\n== A1: noise chain vs sketch quality (n=128, m=64) ==");
    let n = 128;
    let (a, b) = correlated_pair(n, 0.5, 1);
    let want = exact_matmul_tn(&a, &b);
    println!("{:<12} {:>14} {:>14}", "noise", "mean rel err", "ci95");
    for (name, noise) in [
        ("ideal", NoiseModel::ideal()),
        ("realistic", NoiseModel::realistic()),
        ("harsh", NoiseModel::harsh()),
    ] {
        let mut r = Running::new();
        let t0 = Instant::now();
        for t in 0..4u64 {
            let dev = OpuDevice::new(OpuConfig::new(50 + t, 64, n).with_noise(noise.clone()));
            let s = OpuSketcher::new(Arc::new(dev));
            r.push(rel_frobenius_error(&want, &approx_matmul_tn(&s, &a, &b)));
        }
        rows.push(Summary::flat(
            format!("A1 approx_matmul noise={name}"),
            4,
            t0.elapsed().as_nanos() as f64 / 4.0,
        ));
        println!("{name:<12} {:>14.5} {:>14.5}", r.mean(), r.ci95());
    }
}

/// A2: bit-plane depth vs fidelity to the device's own linear oracle.
fn ablation_bits(rows: &mut Vec<Summary>) {
    println!("\n== A2: DMD bit depth vs projection fidelity (ideal noise) ==");
    let n = 128;
    let mut rng = Xoshiro256::new(2);
    let x = Mat::gaussian(n, 8, 1.0, &mut rng);
    println!("{:<8} {:>14} {:>12}", "bits", "rel err", "frames/col");
    for bits in [2usize, 4, 6, 8, 10, 12] {
        let dev = OpuDevice::new(OpuConfig::ideal(9, 64, n).with_bits(bits));
        let g = dev.effective_matrix();
        let want = matmul(&g, &x);
        let t0 = Instant::now();
        let got = dev.project(&x);
        rows.push(Summary::flat(
            format!("A2 opu.project bits={bits}"),
            1,
            t0.elapsed().as_nanos() as f64,
        ));
        println!(
            "{bits:<8} {:>14.2e} {:>12}",
            rel_frobenius_error(&want, &got),
            4 * bits
        );
    }
}

/// A3: anchor length vs calibration health and fidelity.
fn ablation_anchor(rows: &mut Vec<Summary>) {
    println!("\n== A3: anchor length vs calibration yield / fidelity ==");
    let n = 128;
    let mut rng = Xoshiro256::new(3);
    let x = Mat::gaussian(n, 4, 1.0, &mut rng);
    println!("{:<8} {:>10} {:>14}", "anchor", "yield %", "rel err");
    for anchor in [2usize, 8, 32, 128] {
        let cfg = OpuConfig {
            anchor_len: anchor,
            ..OpuConfig::new(11, 64, n).with_noise(NoiseModel::realistic())
        };
        let t0 = Instant::now();
        let dev = OpuDevice::new(cfg);
        rows.push(Summary::flat(
            format!("A3 calibrate anchor={anchor}"),
            1,
            t0.elapsed().as_nanos() as f64,
        ));
        let g = dev.effective_matrix();
        let want = matmul(&g, &x);
        let got = dev.project(&x);
        println!(
            "{anchor:<8} {:>10.1} {:>14.5}",
            dev.calibration().yield_fraction() * 100.0,
            rel_frobenius_error(&want, &got)
        );
    }
}

/// A4: dynamic batching vs service throughput (host arm, CPU-bound).
fn ablation_batching(rows: &mut Vec<Summary>) {
    println!("\n== A4: batching deadline vs throughput (64 concurrent projections) ==");
    println!("{:<14} {:>12} {:>16}", "max_wait_us", "jobs/s", "mean batch cols");
    for wait_us in [0u64, 100, 500, 2000] {
        let coord = Coordinator::start(CoordinatorConfig {
            workers: 8,
            policy: Policy::ForceHost,
            batch: BatchConfig {
                max_wait: Duration::from_micros(wait_us),
                max_cols: 512,
                noise: NoiseModel::ideal(),
                ..Default::default()
            },
            ..Default::default()
        })
        .unwrap();
        let mut rng = Xoshiro256::new(4);
        let jobs: Vec<Mat> = (0..64).map(|_| Mat::gaussian(256, 2, 1.0, &mut rng)).collect();
        let t0 = Instant::now();
        let tickets: Vec<_> = jobs
            .into_iter()
            .map(|x| coord.submit(Job::Projection { data: x, m: 64 }))
            .collect();
        for t in tickets {
            t.wait().unwrap();
        }
        let dt = t0.elapsed().as_secs_f64();
        rows.push(Summary::flat(
            format!("A4 projection wait_us={wait_us}"),
            64,
            dt * 1e9 / 64.0,
        ));
        println!(
            "{wait_us:<14} {:>12.1} {:>16.1}",
            64.0 / dt,
            coord.metrics.mean_batch_cols()
        );
        coord.shutdown();
    }
}
