//! Bench: the adaptive-accuracy layer — columns, passes and iterations
//! bought back by error-aware drivers.
//!
//! ```bash
//! cargo bench --bench adaptive [-- --quick]
//! ```
//!
//! Three headline measurements, each with a hard gate:
//!
//! 1. **Hutch++ vs Hutchinson** — seeded RMS relative trace error on a
//!    decaying spectrum: Hutch++ at *half* the projection columns must
//!    match or beat Hutchinson (the O(1/eps) vs O(1/eps^2) claim);
//! 2. **incremental rangefinder** — an adaptive randsvd must stop well
//!    below its rank cap on a numerically low-rank target while meeting
//!    its tolerance;
//! 3. **sketch-and-precondition LSQR** — on an ill-conditioned system
//!    the sketch-preconditioned solver must converge where plain LSQR
//!    (identity preconditioner) stalls, or at least halve its
//!    iterations.
//!
//! Emits BENCH_adaptive.json.

use std::time::Instant;

use photonic_randnla::bench::{self, Gate, Summary};
use photonic_randnla::linalg::{self, rel_frobenius_error, Mat};
use photonic_randnla::perfmodel::{adaptive_range_ms, digital_sketch_ms, SketchKind};
use photonic_randnla::randnla::backend::DigitalSketcher;
use photonic_randnla::randnla::lstsq::{precond_refine, LsqrOpts};
use photonic_randnla::randnla::{
    adaptive_range_digital, hutchinson, hutchpp_digital, randsvd, RandSvdOpts, RangeFinderOpts,
};
use photonic_randnla::rng::Xoshiro256;
use photonic_randnla::workload::{matrix_with_spectrum, psd_with_spectrum, Spectrum};

fn rms_rel(truth: f64, estimates: &[f64]) -> f64 {
    let sq: f64 = estimates
        .iter()
        .map(|e| {
            let r = (e - truth) / truth;
            r * r
        })
        .sum();
    (sq / estimates.len() as f64).sqrt()
}

fn main() {
    let quick = bench::quick_mode();
    let n = if quick { 64 } else { 128 };
    let trials = if quick { 8u64 } else { 16 };
    let mut rows = Vec::new();
    let mut gates: Vec<Gate> = Vec::new();

    // ---- 1. Hutch++ vs Hutchinson at equal error -----------------------
    let a = psd_with_spectrum(n, Spectrum::Exponential { decay: 0.85 }, 1);
    let truth = a.trace();
    // Hutchinson's budget; Hutch++ gets half. Kept at 64 even in quick
    // mode: a smaller budget narrows the variance gap the gate measures.
    let m = 64;

    let t0 = Instant::now();
    let hutch_est: Vec<f64> = (0..trials)
        .map(|t| hutchinson(&DigitalSketcher::new(m, n, 1_000 + 31 * t), &a))
        .collect();
    let hutch_ns = t0.elapsed().as_nanos() as f64 / trials as f64;
    let t0 = Instant::now();
    let hpp_est: Vec<f64> = (0..trials)
        .map(|t| hutchpp_digital(&a, m / 2, 2_000 + 37 * t))
        .collect();
    let hpp_ns = t0.elapsed().as_nanos() as f64 / trials as f64;

    let hutch_rms = rms_rel(truth, &hutch_est);
    let hpp_rms = rms_rel(truth, &hpp_est);
    rows.push(Summary::flat(format!("hutchinson n={n} m={m}"), trials, hutch_ns));
    rows.push(Summary::flat(format!("hutch++ n={n} m={}", m / 2), trials, hpp_ns));
    println!(
        "trace: hutchinson rms {hutch_rms:.4} @ {m} cols | hutch++ rms {hpp_rms:.4} @ {} cols",
        m / 2
    );
    gates.push(Gate::new(
        "hutch++ at half budget matches hutchinson",
        hpp_rms <= hutch_rms,
        format!("hutch++ rms {hpp_rms:.4} @ {} cols vs hutchinson {hutch_rms:.4} @ {m}", m / 2),
    ));

    // ---- 2. adaptive rangefinder / randsvd -----------------------------
    let rank = 8;
    let cap = n / 2;
    let tol = 0.05;
    let target = matrix_with_spectrum(n, Spectrum::LowRankPlusNoise { rank, noise: 1e-3 }, 2);
    let t0 = Instant::now();
    let range = adaptive_range_digital(
        &target,
        RangeFinderOpts { block: rank / 2, max_rank: cap, tol },
        3,
    );
    let range_ns = t0.elapsed().as_nanos() as f64;
    rows.push(Summary::flat(
        format!("adaptive rangefinder n={n} tol={tol}"),
        1,
        range_ns,
    ));
    println!(
        "rangefinder: {} columns in {} passes (cap {cap}), gate rel err {:.2e}",
        range.q.cols, range.passes, range.rel_err
    );
    gates.push(Gate::new(
        "rangefinder stops early",
        range.converged && range.q.cols < cap,
        format!("{} cols (cap {cap}), converged {}", range.q.cols, range.converged),
    ));

    let s = DigitalSketcher::new(cap, n, 4);
    let t0 = Instant::now();
    let r = randsvd(
        &s,
        &target,
        RandSvdOpts {
            rank: cap - 8,
            oversample: 8,
            power_iters: 0,
            tol: Some(tol),
            block: rank / 2,
        },
    );
    let svd_ns = t0.elapsed().as_nanos() as f64;
    rows.push(Summary::flat(format!("adaptive randsvd n={n} tol={tol}"), 1, svd_ns));
    let rec = linalg::reconstruct(&r.u, &r.s, &r.vt);
    let rel = rel_frobenius_error(&target, &rec);
    println!("adaptive randsvd: rank {} (cap {}), measured rel err {rel:.2e}", r.s.len(), cap - 8);
    gates.push(Gate::new(
        "adaptive randsvd meets tolerance",
        rel <= tol,
        format!("rel err {rel:.2e} (tol {tol})"),
    ));

    // Model context: what the router would charge for those passes.
    let priced = adaptive_range_ms(SketchKind::Dense, n, rank / 2, 1, range.passes);
    let fixed = digital_sketch_ms(SketchKind::Dense, n, cap, 1);
    println!(
        "perfmodel: {} adaptive passes priced {priced:.4} ms vs fixed {cap}-col sketch \
         {fixed:.4} ms",
        range.passes
    );

    // ---- 3. sketch-and-precondition LSQR -------------------------------
    let rows_n = if quick { 192 } else { 384 };
    let d = 8;
    let mut rng = Xoshiro256::new(5);
    let mut a_ls = Mat::gaussian(rows_n, d, 1.0, &mut rng);
    for j in 0..d {
        let sc = 10f64.powf(-3.0 * j as f64 / (d - 1) as f64);
        for i in 0..rows_n {
            *a_ls.at_mut(i, j) *= sc;
        }
    }
    let x_true: Vec<f64> = (0..d).map(|_| rng.next_normal()).collect();
    let mut b = linalg::matvec(&a_ls, &x_true);
    for v in b.iter_mut() {
        *v += 0.1 * rng.next_normal();
    }
    let opts = LsqrOpts { tol: 1e-10, max_iters: 48 };
    let sk = DigitalSketcher::new(8 * d, rows_n, 6);
    let sa = sk.project(&a_ls);
    let sb_mat = sk.project(&Mat::from_fn(rows_n, 1, |i, _| b[i]));
    let sb: Vec<f64> = (0..sb_mat.rows).map(|i| sb_mat.at(i, 0)).collect();

    let t0 = Instant::now();
    let refined = precond_refine(&a_ls, &b, &sa, &sb, opts);
    let refined_ns = t0.elapsed().as_nanos() as f64;
    let t0 = Instant::now();
    let plain = precond_refine(&a_ls, &b, &Mat::eye(d), &vec![0.0; d], opts);
    let plain_ns = t0.elapsed().as_nanos() as f64;
    rows.push(Summary::flat(format!("lstsq precond-lsqr {rows_n}x{d}"), 1, refined_ns));
    rows.push(Summary::flat(format!("lstsq plain-lsqr {rows_n}x{d}"), 1, plain_ns));
    println!(
        "lstsq (cond ~1e3): preconditioned {} iters (converged: {}) vs plain {} iters \
         (converged: {})",
        refined.iters, refined.converged, plain.iters, plain.converged
    );
    gates.push(Gate::new(
        "sketch preconditioning halves lsqr iterations",
        refined.converged && !(plain.converged && refined.iters * 2 > plain.iters),
        format!(
            "preconditioned {} iters (converged {}) vs plain {} (converged {})",
            refined.iters, refined.converged, plain.iters, plain.converged
        ),
    ));

    bench::report("adaptive-accuracy drivers", &rows);
    println!(
        "\nheadline: accuracy is a knob — half-budget hutch++, early-stop rangefinder, \
         residual-guaranteed lstsq"
    );
    bench::finish("adaptive", &rows, &gates);
}
