//! Bench: hot-path microbenchmarks — the §Perf baseline and regression
//! guard for every layer's critical loop.
//!
//! ```bash
//! cargo bench --bench hotpath
//! ```

use photonic_randnla::bench::{report, run, Config};
use photonic_randnla::linalg::{self, Mat};
use photonic_randnla::opu::{NoiseModel, OpuConfig, OpuDevice, TransmissionMatrix};
use photonic_randnla::rng::{philox, Philox4x32, Xoshiro256};

fn main() {
    let mut rows = Vec::new();
    let cfg = Config::default();
    let quick = Config::quick();
    let mut rng = Xoshiro256::new(1);

    // RNG substrate.
    let p = Philox4x32::new(7);
    rows.push(run("philox 1M normals", cfg, || {
        let mut acc = 0.0;
        for i in 0..250_000u64 {
            acc += philox::block_to_normals(p.block_at(i, 0))[0];
        }
        std::hint::black_box(acc);
    }));
    let mut xr = Xoshiro256::new(3);
    rows.push(run("xoshiro 1M normals", cfg, || {
        let mut acc = 0.0;
        for _ in 0..1_000_000 {
            acc += xr.next_normal();
        }
        std::hint::black_box(acc);
    }));

    // TM streaming field (the OPU inner loop).
    let tm = TransmissionMatrix::new(5, 256, 512);
    let x = Mat::gaussian(512, 16, 1.0, &mut rng);
    rows.push(run("tm.field 256x512 k=16", quick, || {
        std::hint::black_box(tm.field(&x));
    }));

    // Full OPU projection pipeline (encode + 32 exposures + recombine).
    let dev = OpuDevice::new(OpuConfig::new(7, 128, 256).with_noise(NoiseModel::realistic()));
    let xd = Mat::gaussian(256, 8, 1.0, &mut rng);
    rows.push(run("opu.project 128x256 k=8", quick, || {
        std::hint::black_box(dev.project(&xd));
    }));

    // Exact-GEMM substrate.
    for n in [128usize, 256, 512] {
        let a = Mat::gaussian(n, n, 1.0, &mut rng);
        let b = Mat::gaussian(n, n, 1.0, &mut rng);
        rows.push(run(&format!("matmul {n}^3"), quick, || {
            std::hint::black_box(linalg::matmul(&a, &b));
        }));
    }

    // Factorizations on compressed-domain sizes.
    let tall = Mat::gaussian(512, 64, 1.0, &mut rng);
    rows.push(run("thin_qr 512x64", quick, || {
        std::hint::black_box(linalg::thin_qr(&tall));
    }));
    let small = Mat::gaussian(96, 96, 1.0, &mut rng);
    rows.push(run("jacobi_svd 96x96", quick, || {
        std::hint::black_box(linalg::svd(&small));
    }));

    // Bit-plane codec.
    let frames = Mat::gaussian(1024, 16, 1.0, &mut rng);
    rows.push(run("bitplane encode 1024x16 @8b", cfg, || {
        std::hint::black_box(photonic_randnla::opu::encoding::encode(&frames, 8));
    }));

    report("hot paths", &rows);
    println!("\nCSV");
    println!("name,iters,mean_ns,p50_ns,p99_ns,min_ns,max_ns");
    for r in &rows {
        println!("{}", r.csv_row());
    }
}
