//! Bench: hot-path microbenchmarks — the §Perf baseline and regression
//! guard for every layer's critical loop.
//!
//! ```bash
//! cargo bench --bench hotpath            # full budgets, 2x GEMM gate
//! cargo bench --bench hotpath -- --quick # CI smoke: small budgets,
//!                                        # relaxed gate, same checks
//! ```
//!
//! Emits BENCH_hotpath.json (shared bench schema: cases + gates) for
//! cross-PR tracking and exits non-zero when the packed GEMM regresses
//! against the in-file seed (axpy) kernel — kernel regressions fail CI
//! instead of landing silently.

use photonic_randnla::bench::{finish, quick_mode, report, run, Config, Gate};
use photonic_randnla::linalg::{self, Mat};
use photonic_randnla::opu::{NoiseModel, OpuConfig, OpuDevice, TransmissionMatrix};
use photonic_randnla::parallel;
use photonic_randnla::rng::{philox, Philox4x32, Xoshiro256};

/// The seed GEMM this repo shipped before the packed microkernel: an
/// L1-blocked ikj axpy loop over row bands. Kept here as the fixed
/// baseline the packed kernel is gated against.
fn seed_axpy_matmul(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.rows);
    const KC: usize = 256;
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let t = parallel::num_threads();
    let band = (m / (4 * t).max(1)).clamp(4, 64).max(1);
    let mut c = Mat::zeros(m, n);
    parallel::par_chunks_mut(&mut c.data, band * n, |start, band_c| {
        let i0 = start / n;
        let rows_in_band = band_c.len() / n;
        for kb in (0..k).step_by(KC) {
            let kend = (kb + KC).min(k);
            for ii in 0..rows_in_band {
                let arow = a.row(i0 + ii);
                let crow = &mut band_c[ii * n..(ii + 1) * n];
                for kk in kb..kend {
                    let aik = arow[kk];
                    if aik == 0.0 {
                        continue;
                    }
                    let brow = b.row(kk);
                    for (cv, bv) in crow.iter_mut().zip(brow) {
                        *cv += aik * bv;
                    }
                }
            }
        }
    });
    c
}

fn main() {
    let quick = quick_mode();
    let cfg = if quick { Config::quick() } else { Config::default() };
    let heavy = Config::quick();
    let mut rows = Vec::new();
    let mut rng = Xoshiro256::new(1);

    // RNG substrate.
    let p = Philox4x32::new(7);
    rows.push(run("philox 1M normals", cfg, || {
        let mut acc = 0.0;
        for i in 0..250_000u64 {
            acc += philox::block_to_normals(p.block_at(i, 0))[0];
        }
        std::hint::black_box(acc);
    }));
    let mut xr = Xoshiro256::new(3);
    rows.push(run("xoshiro 1M normals", cfg, || {
        let mut acc = 0.0;
        for _ in 0..1_000_000 {
            acc += xr.next_normal();
        }
        std::hint::black_box(acc);
    }));

    // TM streaming field (the OPU inner loop).
    let tm = TransmissionMatrix::new(5, 256, 512);
    let x = Mat::gaussian(512, 16, 1.0, &mut rng);
    rows.push(run("tm.field 256x512 k=16", heavy, || {
        std::hint::black_box(tm.field(&x));
    }));

    // Full OPU projection pipeline (encode + 32 exposures + recombine).
    let dev = OpuDevice::new(OpuConfig::new(7, 128, 256).with_noise(NoiseModel::realistic()));
    let xd = Mat::gaussian(256, 8, 1.0, &mut rng);
    rows.push(run("opu.project 128x256 k=8", heavy, || {
        std::hint::black_box(dev.project(&xd));
    }));

    // Exact-GEMM substrate: packed microkernel vs the seed axpy kernel.
    let mut packed_512 = None;
    let mut seed_512 = None;
    for n in [128usize, 256, 512] {
        let a = Mat::gaussian(n, n, 1.0, &mut rng);
        let b = Mat::gaussian(n, n, 1.0, &mut rng);
        let packed = run(&format!("matmul {n}^3 (packed)"), heavy, || {
            std::hint::black_box(linalg::matmul(&a, &b));
        });
        let seed = run(&format!("matmul {n}^3 (seed axpy)"), heavy, || {
            std::hint::black_box(seed_axpy_matmul(&a, &b));
        });
        if n == 512 {
            packed_512 = Some(packed.mean_ns);
            seed_512 = Some(seed.mean_ns);
        }
        rows.push(packed);
        rows.push(seed);
    }

    // A @ B^T (banded task grain; used by workload generators + sketch.rs).
    let ant = Mat::gaussian(512, 384, 1.0, &mut rng);
    let bnt = Mat::gaussian(512, 384, 1.0, &mut rng);
    rows.push(run("matmul_nt 512x384 @ (512x384)^T", heavy, || {
        std::hint::black_box(linalg::matmul_nt(&ant, &bnt));
    }));

    // Parallel trace contractions (Hutchinson / triangle hot loops).
    let ta = Mat::gaussian(512, 512, 1.0, &mut rng);
    let tb = Mat::gaussian(512, 512, 1.0, &mut rng);
    rows.push(run("trace_of_product 512", heavy, || {
        std::hint::black_box(linalg::trace_of_product(&ta, &tb));
    }));

    // Factorizations on compressed-domain sizes.
    let tall = Mat::gaussian(512, 64, 1.0, &mut rng);
    rows.push(run("thin_qr 512x64", heavy, || {
        std::hint::black_box(linalg::thin_qr(&tall));
    }));
    let small = Mat::gaussian(96, 96, 1.0, &mut rng);
    rows.push(run("jacobi_svd 96x96", heavy, || {
        std::hint::black_box(linalg::svd(&small));
    }));

    // Bit-plane codec.
    let frames = Mat::gaussian(1024, 16, 1.0, &mut rng);
    rows.push(run("bitplane encode 1024x16 @8b", cfg, || {
        std::hint::black_box(photonic_randnla::opu::encoding::encode(&frames, 8));
    }));

    report("hot paths", &rows);
    println!("\nCSV");
    println!("name,iters,mean_ns,p50_ns,p99_ns,min_ns,max_ns");
    for r in &rows {
        println!("{}", r.csv_row());
    }

    // Regression gate: packed >= 2x over the seed kernel at 512^3
    // (>= 1.3x in --quick smoke runs, where budgets are tiny and CI
    // runners are noisy).
    let (seed_ns, packed_ns) = (seed_512.unwrap(), packed_512.unwrap());
    let speedup = seed_ns / packed_ns;
    let floor = if quick { 1.3 } else { 2.0 };
    let gates = vec![Gate::new(
        "packed GEMM speedup at 512^3",
        speedup >= floor,
        format!("{speedup:.2}x (need >= {floor}x)"),
    )];
    finish("hotpath", &rows, &gates);
}
