//! Bench: client-plane submit throughput — store handles vs inline
//! operand shipping.
//!
//! ```bash
//! cargo bench --bench client_plane [-- --quick]
//! ```
//!
//! The session API's hot-path claim: k jobs against one uploaded
//! operand should cost k `Arc` clones, not k deep copies. Two series
//! over the same projection workload (one n x 64 operand, k jobs):
//!
//! - **inline** — the legacy path: every `submit(Job)` re-ships the
//!   operand (the client clones it to keep its copy, exactly what a
//!   multi-pass algorithm without handles must do);
//! - **handle** — upload once, then submit k `JobSpec`s referencing the
//!   store handle (payload rides one `Arc` end-to-end).
//!
//! The timed region is submission only (what the client observes as
//! submit latency); jobs drain outside it. Acceptance gate: handle-path
//! submit throughput >= 2x inline (1.5x in --quick smoke mode).
//! Emits BENCH_client_plane.json.

use std::time::Instant;

use photonic_randnla::bench::{self, Gate, Summary};
use photonic_randnla::coordinator::{
    BatchConfig, Coordinator, CoordinatorConfig, Job, JobSpec, OperandRef, Policy, PoolConfig,
    SubmitOptions,
};
use photonic_randnla::linalg::Mat;
use photonic_randnla::opu::NoiseModel;
use photonic_randnla::rng::Xoshiro256;

fn coordinator() -> Coordinator {
    Coordinator::start(CoordinatorConfig {
        workers: 4,
        policy: Policy::ForceHost,
        batch: BatchConfig {
            max_wait: std::time::Duration::from_micros(50),
            noise: NoiseModel::ideal(),
            ..Default::default()
        },
        pool: PoolConfig { pjrt_replicas: 0, ..Default::default() },
        ..Default::default()
    })
    .expect("coordinator start")
}

fn main() {
    let quick = bench::quick_mode();
    let n = if quick { 1024 } else { 4096 };
    let cols = 64usize;
    let m = 16usize;
    let k = if quick { 16u64 } else { 32 };
    let reps = if quick { 3 } else { 5 };
    let mib = (n * cols * 8) as f64 / (1024.0 * 1024.0);

    println!(
        "== client plane: {k} jobs sharing one {n} x {cols} operand ({mib:.1} MiB), m = {m} =="
    );

    let c = coordinator();
    let mut rng = Xoshiro256::new(1);
    let x = Mat::gaussian(n, cols, 1.0, &mut rng);

    // Inline path: every submit re-ships the operand.
    let mut inline_best = f64::INFINITY;
    let mut inline_result: Option<Mat> = None;
    for _ in 0..reps {
        let t0 = Instant::now();
        let tickets: Vec<_> = (0..k)
            .map(|_| c.submit(Job::Projection { data: x.clone(), m }))
            .collect();
        let dt = t0.elapsed().as_nanos() as f64;
        inline_best = inline_best.min(dt / k as f64);
        for t in tickets {
            let r = t.wait().expect("inline job");
            inline_result.get_or_insert_with(|| r.payload.matrix().unwrap().clone());
        }
    }

    // Handle path: upload once, k Arc-clean submissions.
    let id = c.upload(x.clone()).expect("upload");
    let mut handle_best = f64::INFINITY;
    let mut handle_result: Option<Mat> = None;
    for _ in 0..reps {
        let t0 = Instant::now();
        let tickets: Vec<_> = (0..k)
            .map(|_| {
                c.submit_spec(
                    JobSpec::Projection { data: OperandRef::Handle(id), m },
                    SubmitOptions::default(),
                )
                .expect("handle submit")
            })
            .collect();
        let dt = t0.elapsed().as_nanos() as f64;
        handle_best = handle_best.min(dt / k as f64);
        for t in tickets {
            let r = t.wait().expect("handle job");
            handle_result.get_or_insert_with(|| r.payload.matrix().unwrap().clone());
        }
    }

    // Same signature => same operator: both paths must agree bitwise.
    assert_eq!(
        inline_result.unwrap(),
        handle_result.unwrap(),
        "handle and inline submissions of one operand diverged"
    );

    let rows = vec![
        Summary::flat(format!("inline submit n={n} k={cols}"), k, inline_best),
        Summary::flat(format!("handle submit n={n} k={cols}"), k, handle_best),
    ];
    bench::report("client plane submit path", &rows);

    println!(
        "\nstore: {} operands resident, {} B",
        c.store().len(),
        c.store().bytes()
    );
    c.shutdown();

    let speedup = inline_best / handle_best;
    let floor = if quick { 1.5 } else { 2.0 };
    println!("\nheadline: handle-path submit is {speedup:.1}x the inline path");
    let gates = vec![Gate::new(
        "handle-path submit speedup over inline",
        speedup >= floor,
        format!("{speedup:.1}x (need >= {floor}x)"),
    )];
    bench::finish("client_plane", &rows, &gates);
}
