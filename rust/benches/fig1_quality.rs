//! Bench: Fig. 1 — quality series for all four RandNLA tasks.
//!
//! ```bash
//! cargo bench --bench fig1_quality            # default n=192, 3 trials
//! PHOTON_FIG1_N=256 PHOTON_FIG1_TRIALS=5 cargo bench --bench fig1_quality
//! ```
//!
//! This is the figure-regeneration harness: it prints the same
//! (compression -> relative error) series the paper plots, for the optical
//! and digital arms, and asserts the headline "optical == numerical".
//! Emits BENCH_fig1_quality.json (shared bench schema) with the headline
//! check as its gate.

use photonic_randnla::bench::{self, Gate, Summary};
use photonic_randnla::opu::NoiseModel;
use photonic_randnla::reports::{fig1, print_rows};

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let cfg = fig1::Fig1Config {
        n: env_usize("PHOTON_FIG1_N", 192),
        trials: env_usize("PHOTON_FIG1_TRIALS", 3),
        seed: 7,
        noise: NoiseModel::realistic(),
        ..Default::default()
    };
    println!("Fig. 1 quality sweep: n={} trials={} (realistic noise)", cfg.n, cfg.trials);

    let t0 = std::time::Instant::now();
    let rows = fig1::all_panels(&cfg);
    let sweep_ns = t0.elapsed().as_nanos() as f64;
    print_rows("Fig. 1 — optical vs numerical quality", &rows);
    println!("(swept in {:.1}s)", sweep_ns / 1e9);

    let headline = fig1::optical_matches_numerical(&rows, 0.9);
    let gate = Gate::new(
        "optical == numerical within tolerance",
        headline.is_ok(),
        match &headline {
            Ok(()) => format!("{} series points, tolerance factor 0.9", rows.len()),
            Err(e) => e.clone(),
        },
    );
    let cases = vec![Summary::flat(
        format!("fig1 sweep n={} trials={}", cfg.n, cfg.trials),
        1,
        sweep_ns,
    )];
    bench::finish("fig1_quality", &cases, &[gate]);
}
