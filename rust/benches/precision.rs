//! Bench: mixed-precision projection arms — the tentpole's acceptance
//! gates.
//!
//! ```bash
//! cargo bench --bench precision            # full budgets, 2x gate
//! cargo bench --bench precision -- --quick # CI smoke, 1.5x gate
//! ```
//!
//! Two hard gates:
//!
//! 1. **f32 packed projection throughput** — at the paper's host-arm
//!    scale (n = 4096, m = 512, k = 16) the packed compensated f32
//!    kernel must project >= 2x faster than the f64 baseline (>= 1.5x
//!    in `--quick` smoke runs, where budgets are tiny and CI runners
//!    are noisy). Operands are packed once outside the timed loop: the
//!    serving plane holds tier-resident operands, so packing is an
//!    upload-time cost, not a per-projection one.
//! 2. **bf16 RandSVD accuracy** — a seeded RandSVD through the
//!    coordinator at the Bf16 tier (Ootomo split + compensated f32
//!    accumulation) must keep its singular-value relative RMS error
//!    within 1e-2 of the same seeded run at f64 — the documented
//!    `Precision::Bf16.tier_tol()` bound, measured end to end.
//!
//! Emits BENCH_precision.json (shared bench schema) and exits non-zero
//! on a gate miss — this target is part of the CI bench smoke list.

use std::time::Instant;

use photonic_randnla::bench::{finish, quick_mode, report, run, Config, Gate, Summary};
use photonic_randnla::coordinator::{
    BatchConfig, Coordinator, CoordinatorConfig, JobSpec, OperandRef, Policy, PoolConfig,
    Precision, SubmitOptions,
};
use photonic_randnla::linalg::{self, Mat, MatF32};
use photonic_randnla::opu::NoiseModel;
use photonic_randnla::rng::Xoshiro256;
use photonic_randnla::workload::{matrix_with_spectrum, Spectrum};

const N: usize = 4096;
const M: usize = 512;
const K: usize = 16;

fn coordinator() -> Coordinator {
    Coordinator::start(CoordinatorConfig {
        workers: 4,
        policy: Policy::ForceHost,
        batch: BatchConfig {
            max_wait: std::time::Duration::from_micros(50),
            noise: NoiseModel::ideal(),
            ..Default::default()
        },
        pool: PoolConfig { pjrt_replicas: 0, ..Default::default() },
        ..Default::default()
    })
    .expect("coordinator start")
}

/// Seeded RandSVD through the coordinator at one tier; returns the
/// singular values and the wall time. Same handle + same spec => the
/// operator draws are identical across tiers (operator identity is
/// tier-independent), so the spectra differ only by arithmetic.
fn seeded_svd(c: &Coordinator, a: &Mat, rank: usize, precision: Precision) -> (Vec<f64>, f64) {
    let t0 = Instant::now();
    let resp = c
        .run_spec(
            JobSpec::RandSvd {
                a: OperandRef::Inline(a.clone()),
                rank,
                oversample: 8,
                power_iters: 1,
                publish_q: false,
                tol: None,
            },
            SubmitOptions::default().with_precision(precision),
        )
        .expect("randsvd");
    let ns = t0.elapsed().as_nanos() as f64;
    assert_eq!(resp.precision, precision, "coordinator ran at the wrong tier");
    let (_, s, _) = resp.payload.svd().expect("svd payload");
    (s.to_vec(), ns)
}

fn main() {
    let quick = quick_mode();
    // The projection GEMM at this scale runs in milliseconds; moderate
    // budgets give stable means in both modes.
    let cfg = if quick {
        Config {
            warmup: std::time::Duration::from_millis(20),
            measure: std::time::Duration::from_millis(200),
            min_iters: 3,
            max_iters: 1000,
        }
    } else {
        Config::quick()
    };

    let mut rng = Xoshiro256::new(42);
    // S is the m x n sketch operator, A the n x k operand block — the
    // host arm's projection hot loop.
    let s_op = Mat::gaussian(M, N, 1.0, &mut rng);
    let a_op = Mat::gaussian(N, K, 1.0, &mut rng);
    let s32 = MatF32::from_mat(&s_op);
    let a32 = MatF32::from_mat(&a_op);

    let mut rows = Vec::new();
    let f64_row = run(&format!("f64 projection {M}x{N} k={K}"), cfg, || {
        std::hint::black_box(linalg::matmul(&s_op, &a_op));
    });
    let f32_row = run(&format!("f32 packed projection {M}x{N} k={K}"), cfg, || {
        std::hint::black_box(linalg::matmul_packed_f32(&s32, &a32));
    });
    // bf16 reference row (split + three compensated products); the
    // accuracy gate below measures this tier end to end instead.
    let bf16_row = run(&format!("bf16 split projection {M}x{N} k={K}"), cfg, || {
        std::hint::black_box(linalg::matmul_bf16(&s_op, &a_op));
    });
    let (f64_ns, f32_ns) = (f64_row.mean_ns, f32_row.mean_ns);
    rows.push(f64_row);
    rows.push(f32_row);
    rows.push(bf16_row);

    // Gate 2 workload: seeded RandSVD spectra, Bf16 vs f64, through the
    // whole serving plane (submit -> resolve -> batcher -> lowp kernel).
    let n_svd = if quick { 160 } else { 256 };
    let rank = 16;
    let target = matrix_with_spectrum(n_svd, Spectrum::Exponential { decay: 0.85 }, 7);
    let c = coordinator();
    let (s_f64, f64_svd_ns) = seeded_svd(&c, &target, rank, Precision::F64);
    let (s_bf16, bf16_svd_ns) = seeded_svd(&c, &target, rank, Precision::Bf16);
    c.shutdown();
    rows.push(Summary::flat(format!("randsvd n={n_svd} r={rank} f64"), 1, f64_svd_ns));
    rows.push(Summary::flat(format!("randsvd n={n_svd} r={rank} bf16"), 1, bf16_svd_ns));
    assert_eq!(s_f64.len(), s_bf16.len(), "tiers returned different ranks");
    let num: f64 = s_f64.iter().zip(&s_bf16).map(|(x, y)| (x - y) * (x - y)).sum();
    let den: f64 = s_f64.iter().map(|x| x * x).sum();
    let rms = (num / den).sqrt();

    report("mixed-precision projection arms", &rows);

    let speedup = f64_ns / f32_ns;
    let floor = if quick { 1.5 } else { 2.0 };
    println!(
        "\nf32 packed speedup over f64 at n={N} m={M} k={K}: {speedup:.2}x | \
         bf16 randsvd spectrum rel RMS vs f64: {rms:.2e}"
    );
    let gates = vec![
        Gate::new(
            "f32 packed projection speedup over f64",
            speedup >= floor,
            format!("{speedup:.2}x (need >= {floor}x)"),
        ),
        Gate::new(
            "bf16 randsvd singular-value RMS error vs f64",
            rms <= Precision::Bf16.tier_tol(),
            format!("rel RMS {rms:.2e} (need <= {:.0e})", Precision::Bf16.tier_tol()),
        ),
    ];
    finish("precision", &rows, &gates);
}
