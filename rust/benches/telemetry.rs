//! Bench: telemetry-plane overhead and scrape responsiveness under a
//! saturated submit workload.
//!
//! ```bash
//! cargo bench --bench telemetry [-- --quick]
//! ```
//!
//! The observability plane (ISSUE 10) only earns its keep if it is
//! close to free: span assembly, stage histograms and drift auditing
//! ride the existing event journal, so arming them must not dent the
//! serving plane. Two series over the *same* seeded projection burst:
//!
//! - **telemetry off** — the seed serving plane: no stage event is
//!   constructed, no registry projector runs;
//! - **telemetry on**  — spans + histograms + drift auditor armed and a
//!   live Prometheus scrape endpoint bound on loopback.
//!
//! Acceptance gates (ISSUE 10):
//! - telemetry-on sustained submit throughput >= 0.9x telemetry-off
//!   (0.85x in --quick, where the short burst amplifies timer noise);
//! - results are bit-identical between the two series job-for-job
//!   (telemetry never touches the data path);
//! - `GET /metrics` answers 200 with a parseable body while the burst
//!   is in flight;
//! - every job of the on-series assembles a span (the overhead number
//!   is measuring a live plane, not a disarmed one).
//!
//! Emits BENCH_telemetry.json.

use std::io::{Read, Write};
use std::sync::atomic::Ordering;
use std::time::{Duration, Instant};

use photonic_randnla::bench::{self, Gate, Summary};
use photonic_randnla::coordinator::{
    BatchConfig, Coordinator, CoordinatorConfig, Job, MetricsServer, Policy, PoolConfig,
};
use photonic_randnla::linalg::Mat;
use photonic_randnla::opu::NoiseModel;
use photonic_randnla::rng::Xoshiro256;
use photonic_randnla::testkit::ephemeral_loopback;

fn coordinator(telemetry: bool) -> Coordinator {
    Coordinator::start(CoordinatorConfig {
        workers: 4,
        policy: Policy::ForceHost,
        batch: BatchConfig {
            max_wait: Duration::from_micros(50),
            noise: NoiseModel::ideal(),
            ..Default::default()
        },
        pool: PoolConfig { pjrt_replicas: 0, ..Default::default() },
        telemetry,
        ..Default::default()
    })
    .expect("coordinator start")
}

/// The seeded burst both series share: `submits` small dense
/// projections (batcher-merge-friendly, so the per-job cost is
/// coordination — exactly where telemetry overhead would show).
fn burst(seed: u64, submits: usize) -> Vec<Mat> {
    let mut rng = Xoshiro256::new(seed);
    (0..submits).map(|_| Mat::gaussian(64, 2, 1.0, &mut rng)).collect()
}

/// Submit the whole burst, then drain; returns (ns/job, result bits).
fn run_burst(c: &Coordinator, jobs: &[Mat], m: usize) -> (f64, Vec<u64>) {
    let t0 = Instant::now();
    let tickets: Vec<_> = jobs
        .iter()
        .map(|x| c.submit(Job::Projection { data: x.clone(), m }))
        .collect();
    let bits: Vec<u64> = tickets
        .into_iter()
        .map(|t| {
            let p = t.wait().expect("projection").payload;
            let m = p.matrix().unwrap();
            m.data.iter().fold(0u64, |acc, v| acc.wrapping_mul(0x100000001b3).wrapping_add(v.to_bits()))
        })
        .collect();
    (t0.elapsed().as_nanos() as f64 / jobs.len() as f64, bits)
}

/// One blocking HTTP/1.1 scrape against the metrics endpoint.
fn scrape(addr: &std::net::SocketAddr) -> (Duration, String) {
    let t0 = Instant::now();
    let mut s = std::net::TcpStream::connect_timeout(addr, Duration::from_secs(5))
        .expect("connect scrape endpoint");
    s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    s.write_all(b"GET /metrics HTTP/1.1\r\nHost: bench\r\nConnection: close\r\n\r\n")
        .expect("send scrape");
    let mut resp = String::new();
    s.read_to_string(&mut resp).expect("read scrape");
    (t0.elapsed(), resp)
}

fn main() {
    let quick = bench::quick_mode();
    let submits = if quick { 160 } else { 480 };
    let m = 24usize;
    let jobs = burst(71, submits);

    println!("== telemetry overhead: {submits} x (64 x 2, m = {m}) projection submits ==");

    // -- telemetry off (seed serving plane) ---------------------------
    let c_off = coordinator(false);
    let (off_ns, off_bits) = run_burst(&c_off, &jobs, m);
    println!("telemetry off: {:.1}us/job", off_ns / 1e3);
    c_off.shutdown();

    // -- telemetry on, scrape endpoint live ---------------------------
    let c_on = coordinator(true);
    let registry = c_on.telemetry().expect("telemetry armed").clone();
    let render = {
        let registry = registry.clone();
        std::sync::Arc::new(move || registry.render())
    };
    let srv = MetricsServer::start(&ephemeral_loopback(), render).expect("metrics endpoint");
    let addr = srv.addr();

    let (on_ns, on_bits) = run_burst(&c_on, &jobs, m);
    println!("telemetry on : {:.1}us/job (scrape endpoint at http://{addr}/metrics)", on_ns / 1e3);

    // Scrape while a second (untimed) burst is in flight: the endpoint
    // must answer from under load, not just at rest.
    let inflight: Vec<_> = jobs
        .iter()
        .take(submits / 2)
        .map(|x| c_on.submit(Job::Projection { data: x.clone(), m }))
        .collect();
    let (scrape_dt, resp) = scrape(&addr);
    let scrape_ok = resp.starts_with("HTTP/1.1 200")
        && resp.contains("photon_jobs_submitted_total")
        && resp.contains("photon_stage_duration_us_bucket");
    println!("scrape under load: {:.1}ms, 200 + families present = {scrape_ok}", scrape_dt.as_secs_f64() * 1e3);
    for t in inflight {
        t.wait().expect("inflight projection");
    }

    c_on.events().sync();
    let spans = registry.spans_completed();
    let jobs_run = c_on.metrics.completed.load(Ordering::Relaxed);
    println!("spans assembled: {spans} / {jobs_run} completed jobs");
    srv.shutdown();
    c_on.shutdown();

    // Identical seeds and operators: telemetry must never perturb data.
    let bits_identical = off_bits == on_bits;

    let rows = vec![
        Summary::flat(format!("telemetry off submit+drain m={m}"), submits as u64, off_ns),
        Summary::flat(format!("telemetry on  submit+drain m={m}"), submits as u64, on_ns),
    ];
    bench::report("telemetry plane overhead", &rows);

    let ratio = off_ns / on_ns; // throughput_on / throughput_off
    let floor = if quick { 0.85 } else { 0.90 };
    println!("\nheadline: telemetry-on serves at {ratio:.2}x the telemetry-off throughput");
    let gates = vec![
        Gate::new(
            "telemetry-on throughput vs off",
            ratio >= floor,
            format!("{ratio:.2}x (need >= {floor}x)"),
        ),
        Gate::new(
            "data path untouched (bitwise)",
            bits_identical,
            format!("job-for-job result bits identical = {bits_identical}"),
        ),
        Gate::new(
            "scrape responds under load",
            scrape_ok,
            format!("{:.1}ms round trip", scrape_dt.as_secs_f64() * 1e3),
        ),
        Gate::new(
            "spans assembled for the whole burst",
            spans >= jobs_run,
            format!("{spans} spans / {jobs_run} jobs"),
        ),
    ];
    bench::finish("telemetry", &rows, &gates);
}
