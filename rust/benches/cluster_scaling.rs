//! Bench: the scale-out plane — partitioned stream ingest across
//! loopback map workers vs a single worker.
//!
//! ```bash
//! cargo bench --bench cluster_scaling [-- --quick]
//! ```
//!
//! One coordinator front door per configuration; 1 and 4 loopback
//! `WorkerNode`s ingest the same seeded dense stream (begin → chunked
//! append → seal, timed end to end including the summary reduction).
//! The merge-slot grid hands each worker an interleaved quarter of the
//! chunks, so flush compute parallelizes while the coordinator's
//! forwarding stays serial.
//!
//! Acceptance gates: 4-worker ingest throughput >= 1.5x the 1-worker
//! run (1.2x in --quick smoke mode), and the merged Frequent Directions
//! summary is *accurate within its own composed certificate*: the
//! directly measured `‖AᵀA − BᵀB‖₂` sits under the merged Σδ bound,
//! which sits under the classic `‖A‖²_F/(ℓ−k)` guarantee. The merged
//! `S·A` must also be bit-identical across the two worker counts.
//! Emits BENCH_cluster_scaling.json.

use std::time::Instant;

use photonic_randnla::bench::{self, Gate, Summary};
use photonic_randnla::coordinator::{
    BatchConfig, Coordinator, CoordinatorConfig, Policy, PoolConfig, QosClass, StreamOpts,
    TenantRegistry,
};
use photonic_randnla::linalg::{matmul_tn, spectral_norm, Mat};
use photonic_randnla::net::{WireServer, WorkerConfig, WorkerNode};
use photonic_randnla::opu::NoiseModel;
use photonic_randnla::rng::Xoshiro256;
use photonic_randnla::testkit::ephemeral_loopback;

fn coordinator() -> Coordinator {
    Coordinator::start(CoordinatorConfig {
        workers: 2,
        policy: Policy::ForceHost,
        batch: BatchConfig {
            max_wait: std::time::Duration::from_micros(50),
            noise: NoiseModel::ideal(),
            ..Default::default()
        },
        pool: PoolConfig { pjrt_replicas: 0, ..Default::default() },
        ..Default::default()
    })
    .expect("coordinator start")
}

/// Timed begin → append → seal of `a` through `n_workers` loopback
/// nodes; returns (wall ns, merged sa, fd sketch, fd_bound, fro2).
fn ingest_with_workers(
    a: &Mat,
    n_workers: usize,
    chunk: usize,
    opts: StreamOpts,
) -> (f64, Mat, Mat, f64, f64) {
    let tenants = TenantRegistry::new().add("w", "wtok", usize::MAX, QosClass::Batch);
    let srv =
        WireServer::start(coordinator(), &ephemeral_loopback(), tenants).expect("server start");
    let workers: Vec<WorkerNode> = (0..n_workers)
        .map(|_| {
            WorkerNode::connect(&srv.addr().to_string(), "wtok", WorkerConfig::default())
                .expect("worker join")
        })
        .collect();
    while srv.coordinator().cluster().worker_count() < n_workers {
        std::thread::sleep(std::time::Duration::from_millis(2));
    }
    let c = srv.coordinator();
    let t0 = Instant::now();
    let id = c.begin_stream(a.rows, a.cols, opts).expect("begin");
    let mut r0 = 0usize;
    while r0 < a.rows {
        let r1 = (r0 + chunk).min(a.rows);
        c.append_stream(id, &Mat::from_fn(r1 - r0, a.cols, |i, j| a.at(r0 + i, j)))
            .expect("append");
        r0 = r1;
    }
    c.seal_stream(id).expect("seal");
    let wall = t0.elapsed().as_nanos() as f64;
    let sealed = c.streams().sealed(id).expect("sealed");
    let out = (wall, sealed.sa.clone(), sealed.fd.clone(), sealed.fd_bound, sealed.fro2);
    drop(sealed);
    assert!(c.free_stream(id));
    drop(workers);
    srv.shutdown();
    out
}

fn main() {
    let quick = bench::quick_mode();
    let rows = if quick { 2048usize } else { 8192 };
    let cols = 64usize;
    let chunk = rows / 16; // 16 whole-chunk merge slots, 4 per worker at 4
    let ell = 64usize;
    let opts = StreamOpts { chunk_rows: Some(chunk), sketch_m: 256, fd_rank: ell, range_cap: 16 };
    let reps = if quick { 2 } else { 3 };
    let mib = (rows * cols * 8) as f64 / (1024.0 * 1024.0);

    println!(
        "== cluster scaling: {rows} x {cols} stream ({mib:.1} MiB), \
         chunk {chunk}, sketch_m 256, fd ℓ {ell} =="
    );

    let mut rng = Xoshiro256::new(3);
    let a = Mat::gaussian(rows, cols, 1.0, &mut rng);

    let mut best_one = f64::INFINITY;
    let mut best_four = f64::INFINITY;
    let mut one_sa: Option<Mat> = None;
    let mut four: Option<(Mat, Mat, f64, f64)> = None;
    for _ in 0..reps {
        let (wall, sa, _, _, _) = ingest_with_workers(&a, 1, chunk, opts);
        best_one = best_one.min(wall);
        one_sa.get_or_insert(sa);
        let (wall, sa, fd, bound, fro2) = ingest_with_workers(&a, 4, chunk, opts);
        best_four = best_four.min(wall);
        four.get_or_insert((sa, fd, bound, fro2));
    }
    let (four_sa, fd, fd_bound, fro2) = four.unwrap();

    let rows_summary = vec![
        Summary::flat(format!("ingest 1 worker {rows}x{cols}"), rows as u64, best_one / rows as f64),
        Summary::flat(
            format!("ingest 4 workers {rows}x{cols}"),
            rows as u64,
            best_four / rows as f64,
        ),
    ];
    bench::report("cluster ingest (begin + append + seal + reduce)", &rows_summary);

    let speedup = best_one / best_four;
    println!(
        "\nheadline: 4-worker ingest {speedup:.2}x the 1-worker run \
         ({:.1} ms vs {:.1} ms)",
        best_four / 1e6,
        best_one / 1e6
    );

    // Accuracy of the merged summary against its own composed
    // certificate (the reduction carries Σδ through the tree).
    let gram_err = spectral_norm(&matmul_tn(&a, &a).sub(&matmul_tn(&fd, &fd)), 300, 7);
    let guarantee = fro2 / (ell - ell / 2) as f64;
    let within_bound = gram_err <= fd_bound * (1.0 + 1e-9) + 1e-9 * fro2;
    let bound_under_guarantee = fd_bound <= guarantee + 1e-9 * fro2;
    println!(
        "merged FD: gram error {gram_err:.3e} <= composed Σδ {fd_bound:.3e} \
         <= ‖A‖²_F/(ℓ−k) {guarantee:.3e}"
    );
    let sa_identical = one_sa.unwrap() == four_sa;

    let floor = if quick { 1.2 } else { 1.5 };
    let gates = vec![
        Gate::new(
            "4-worker ingest throughput vs 1 worker",
            speedup >= floor,
            format!("{speedup:.2}x (need >= {floor}x)"),
        ),
        Gate::new(
            "merged accuracy within the composed FD bound",
            within_bound && bound_under_guarantee,
            format!(
                "gram err {gram_err:.3e}, Σδ {fd_bound:.3e}, guarantee {guarantee:.3e}"
            ),
        ),
        Gate::new(
            "merged S·A bit-identical across worker counts",
            sa_identical,
            if sa_identical { "1-worker == 4-worker" } else { "bits moved" },
        ),
    ];
    bench::finish("cluster_scaling", &rows_summary, &gates);
}
