//! Bench: pool-scaling ablation — projection throughput vs OPU replicas.
//!
//! ```bash
//! cargo bench --bench pool_scaling
//! ```
//!
//! Two series over the same batched projection workload:
//!
//! - **replication** (fits aperture): identical batches round-robin over
//!   1/2/4 OPU replicas; the headline metric is *simulated device-timeline
//!   throughput* — total projected columns divided by the pool makespan
//!   (max per-replica simulated busy time). This is the quantity a pool
//!   of physical 2 kHz-DMD OPUs scales: each added replica multiplies the
//!   frame budget. Wall-clock jobs/s is printed for reference only (the
//!   *simulator* is host-CPU-bound, so wall time measures this machine,
//!   not the modelled hardware).
//! - **sharding** (exceeds aperture): one oversized projection (2x the
//!   per-replica aperture in both dims) across growing pools; the shard
//!   planner spreads the 2x2 grid, and the simulated makespan drops.
//!
//! Acceptance gate: >= 1.5x simulated throughput at 4 replicas vs 1.
//! Emits BENCH_pool_scaling.json (shared bench schema) with that gate.

use std::time::Instant;

use photonic_randnla::bench::{self, Gate, Summary};
use photonic_randnla::coordinator::{
    BatchConfig, Coordinator, CoordinatorConfig, Device, Job, Policy, PoolConfig,
};
use photonic_randnla::linalg::Mat;
use photonic_randnla::opu::NoiseModel;
use photonic_randnla::rng::Xoshiro256;

const JOBS: usize = 16;
const N: usize = 128;
const M: usize = 32;
const K: usize = 8;

fn opu_coordinator(replicas: usize, aperture: Option<(usize, usize)>) -> Coordinator {
    Coordinator::start(CoordinatorConfig {
        workers: 4,
        policy: Policy::ForceOpu,
        batch: BatchConfig {
            max_cols: K,
            max_wait: std::time::Duration::from_micros(50),
            noise: NoiseModel::ideal(),
            ..Default::default()
        },
        pool: PoolConfig {
            opu_replicas: replicas,
            pjrt_replicas: 0,
            opu_aperture: aperture,
            ..Default::default()
        },
        artifacts_dir: None,
        ..Default::default()
    })
    .expect("coordinator start")
}

/// (simulated makespan ms, wall seconds) of the batched workload.
fn run_workload(replicas: usize) -> (f64, f64) {
    let c = opu_coordinator(replicas, None);
    let mut rng = Xoshiro256::new(1);
    let t0 = Instant::now();
    for _ in 0..JOBS {
        let x = Mat::gaussian(N, K, 1.0, &mut rng);
        c.run(Job::Projection { data: x, m: M }).unwrap();
    }
    let wall = t0.elapsed().as_secs_f64();
    let makespan = c
        .pool()
        .devices()
        .iter()
        .filter(|d| d.id.kind == Device::Opu)
        .map(|d| d.busy_ms())
        .fold(0.0, f64::max);
    c.shutdown();
    (makespan, wall)
}

fn main() {
    println!("== pool scaling: {JOBS} batched projections of {N} -> {M}, k = {K} ==");
    println!(
        "{:<10} {:>16} {:>18} {:>12}",
        "replicas", "sim makespan ms", "sim cols/device-s", "wall jobs/s"
    );
    let total_cols = (JOBS * K) as f64;
    let mut tput = Vec::new();
    let mut cases = Vec::new();
    for replicas in [1usize, 2, 4] {
        let (makespan, wall) = run_workload(replicas);
        let cols_per_s = total_cols / (makespan / 1e3);
        tput.push((replicas, cols_per_s));
        // ns/op = simulated device-timeline makespan per job, the
        // quantity the replication claim scales (wall time measures the
        // host simulator, not the modelled hardware).
        cases.push(Summary::flat(
            format!("replication r={replicas} sim makespan/job"),
            JOBS as u64,
            makespan * 1e6 / JOBS as f64,
        ));
        println!(
            "{replicas:<10} {makespan:>16.2} {cols_per_s:>18.1} {:>12.1}",
            JOBS as f64 / wall
        );
    }
    let t1 = tput.iter().find(|(r, _)| *r == 1).unwrap().1;
    let t4 = tput.iter().find(|(r, _)| *r == 4).unwrap().1;
    let speedup = t4 / t1;
    println!("\nheadline: 4-replica / 1-replica projection throughput = {speedup:.2}x");
    let gates = vec![Gate::new(
        "4-replica simulated throughput over 1-replica",
        speedup >= 1.5,
        format!("{speedup:.2}x (need >= 1.5x)"),
    )];

    // Sharded oversized projection: (2*aperture) in both dims.
    let (am, an) = (M / 2, N / 2);
    println!(
        "\n== aperture sharding: one {N} -> {M} projection on ({am}, {an})-aperture replicas =="
    );
    println!("{:<10} {:>10} {:>16}", "replicas", "shards", "sim makespan ms");
    for replicas in [1usize, 2, 4] {
        let c = opu_coordinator(replicas, Some((am, an)));
        let mut rng = Xoshiro256::new(2);
        let x = Mat::gaussian(N, K, 1.0, &mut rng);
        c.run(Job::Projection { data: x, m: M }).unwrap();
        let shards = c
            .metrics
            .shards_dispatched
            .load(std::sync::atomic::Ordering::Relaxed);
        let makespan = c
            .pool()
            .devices()
            .iter()
            .filter(|d| d.id.kind == Device::Opu)
            .map(|d| d.busy_ms())
            .fold(0.0, f64::max);
        cases.push(Summary::flat(
            format!("sharding r={replicas} sim makespan"),
            1,
            makespan * 1e6,
        ));
        println!("{replicas:<10} {shards:>10} {makespan:>16.2}");
        c.shutdown();
    }
    bench::finish("pool_scaling", &cases, &gates);
}
