//! Integration: rust runtime x AOT artifacts (requires `make artifacts`).
//!
//! Validates the full L1/L2 -> HLO -> PJRT -> rust bridge: every artifact
//! class is executed from rust and checked against the in-crate oracles.
//!
//! The artifact bundle is produced by the python lowering step and the
//! execution needs the `xla` cargo feature; when either is missing every
//! case self-skips (prints why and returns) instead of failing — the
//! default offline build has no PJRT arm by design (see rust/Cargo.toml).

use std::path::PathBuf;

use photonic_randnla::linalg::{self, matmul, rel_frobenius_error, Mat};
use photonic_randnla::opu::{OpuConfig, OpuDevice};
use photonic_randnla::rng::Xoshiro256;
use photonic_randnla::runtime::{ArtifactRegistry, PjrtEngine};

fn artifacts_dir() -> PathBuf {
    std::env::var("PHOTON_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// `None` (with a skip note) when artifacts or the xla runtime are absent.
fn registry() -> Option<ArtifactRegistry> {
    match ArtifactRegistry::open(&artifacts_dir()) {
        Ok(r) => Some(r),
        Err(e) => {
            eprintln!("skipped: artifacts/xla unavailable ({e}); run `make artifacts`");
            None
        }
    }
}

#[test]
fn manifest_lists_all_op_families() {
    let Some(reg) = registry() else { return };
    let names = reg.unit_names();
    for prefix in ["proj_xla", "proj_pallas", "opu_forward", "sketch_sym", "tri_core", "rsvd_range", "gram"] {
        assert!(
            names.iter().any(|n| n.starts_with(prefix)),
            "missing artifact family {prefix}; have {names:?}"
        );
    }
}

#[test]
fn proj_xla_matches_host_matmul() {
    let Some(reg) = registry() else { return };
    let mut rng = Xoshiro256::new(1);
    let r = Mat::gaussian(64, 256, 1.0, &mut rng);
    let a = Mat::gaussian(256, 256, 1.0, &mut rng);
    let got = reg.run("proj_xla_m64_n256", &[&r, &a]).unwrap().into_mat().unwrap();
    let want = matmul(&r, &a);
    assert!(rel_frobenius_error(&want, &got) < 1e-5, "f32 vs f64 GEMM mismatch");
}

#[test]
fn proj_pallas_matches_proj_xla() {
    // The L1 Pallas kernel and the plain XLA dot must agree bit-closely.
    let Some(reg) = registry() else { return };
    let mut rng = Xoshiro256::new(2);
    let r = Mat::gaussian(64, 256, 1.0, &mut rng);
    let a = Mat::gaussian(256, 256, 1.0, &mut rng);
    let xla = reg.run("proj_xla_m64_n256", &[&r, &a]).unwrap().into_mat().unwrap();
    let pallas = reg.run("proj_pallas_m64_n256", &[&r, &a]).unwrap().into_mat().unwrap();
    assert!(rel_frobenius_error(&xla, &pallas) < 1e-5);
}

#[test]
fn opu_forward_artifact_cross_validates_simulator() {
    // |R A|^2 computed by the fused Pallas kernel == host oracle for the
    // same explicit medium; and the device's intensities are physical.
    let Some(reg) = registry() else { return };
    let dev = OpuDevice::new(OpuConfig::ideal(3, 64, 256));
    let mut rng = Xoshiro256::new(4);
    let a = Mat::gaussian(256, 256, 1.0, &mut rng);

    let tm = photonic_randnla::opu::TransmissionMatrix::new(99, 64, 256);
    let (rr, ri) = tm.materialize();
    let got = reg
        .run("opu_forward_m64_n256", &[&rr, &ri, &a])
        .unwrap()
        .into_mat()
        .unwrap();
    let yr = matmul(&rr, &a);
    let yi = matmul(&ri, &a);
    let want = Mat::from_fn(64, 256, |i, j| {
        yr.at(i, j) * yr.at(i, j) + yi.at(i, j) * yi.at(i, j)
    });
    assert!(rel_frobenius_error(&want, &got) < 1e-4);
    let x = Mat::gaussian(256, 4, 1.0, &mut rng);
    let dev_i = dev.intensity_unconstrained(&x);
    assert!(dev_i.data.iter().all(|&v| v >= 0.0));
}

#[test]
fn sketch_sym_artifact_matches_definition() {
    let Some(reg) = registry() else { return };
    let mut rng = Xoshiro256::new(5);
    let g = Mat::gaussian(64, 256, 1.0, &mut rng);
    let a = Mat::gaussian(256, 256, 1.0, &mut rng).symmetrized();
    let got = reg.run("sketch_sym_m64_n256", &[&g, &a]).unwrap().into_mat().unwrap();
    let want = photonic_randnla::randnla::sketch::symmetric_sketch_explicit(&g, &a);
    assert!(rel_frobenius_error(&want, &got) < 1e-4);
}

#[test]
fn tri_core_artifact_matches_trace_cubed() {
    let Some(reg) = registry() else { return };
    let mut rng = Xoshiro256::new(6);
    let b = Mat::gaussian(64, 64, 1.0, &mut rng).symmetrized();
    let got = reg.run("tri_core_m64", &[&b]).unwrap().scalar().unwrap();
    let want = linalg::trace_cubed(&b) / 6.0;
    assert!((got - want).abs() / want.abs().max(1.0) < 1e-4, "{got} vs {want}");
}

#[test]
fn gram_artifact_matches_definition() {
    let Some(reg) = registry() else { return };
    let mut rng = Xoshiro256::new(7);
    let s = Mat::gaussian(64, 256, 1.0, &mut rng);
    let t = Mat::gaussian(64, 256, 1.0, &mut rng);
    let got = reg.run("gram_m64_n256", &[&s, &t]).unwrap().into_mat().unwrap();
    let want = linalg::matmul_tn(&s, &t).scale(1.0 / 64.0);
    assert!(rel_frobenius_error(&want, &got) < 1e-4);
}

#[test]
fn rsvd_range_artifact_matches_power_iteration() {
    let Some(reg) = registry() else { return };
    let mut rng = Xoshiro256::new(8);
    let a = Mat::gaussian(256, 256, 0.08, &mut rng);
    let om = Mat::gaussian(256, 64, 1.0, &mut rng);
    let got = reg
        .run("rsvd_range_n256_l64_q2", &[&a, &om])
        .unwrap()
        .into_mat()
        .unwrap();
    let mut y = matmul(&a, &om);
    for _ in 0..2 {
        y = matmul(&a, &linalg::matmul_tn(&a, &y));
    }
    assert!(rel_frobenius_error(&y, &got) < 1e-3);
}

#[test]
fn padded_projection_correct_for_odd_shapes() {
    let Some(reg) = registry() else { return };
    let mut rng = Xoshiro256::new(9);
    // 50 x 200 does not match any bucket; must pad to (64, 256) and crop.
    let r = Mat::gaussian(50, 200, 1.0, &mut rng);
    let a = Mat::gaussian(200, 30, 1.0, &mut rng);
    let (got, bucket) = reg.run_projection_padded("proj_xla", &r, &a).unwrap();
    assert_eq!(bucket, (64, 256));
    assert_eq!((got.rows, got.cols), (50, 30));
    let want = matmul(&r, &a);
    assert!(rel_frobenius_error(&want, &got) < 1e-5);
}

#[test]
fn padded_projection_chunks_wide_batches() {
    let Some(reg) = registry() else { return };
    let mut rng = Xoshiro256::new(10);
    let r = Mat::gaussian(32, 128, 1.0, &mut rng);
    // 300 columns > the 256-wide bucket: forces column chunking.
    let a = Mat::gaussian(128, 300, 1.0, &mut rng);
    let (got, _) = reg.run_projection_padded("proj_xla", &r, &a).unwrap();
    assert_eq!((got.rows, got.cols), (32, 300));
    let want = matmul(&r, &a);
    assert!(rel_frobenius_error(&want, &got) < 1e-5);
}

#[test]
fn engine_thread_serves_concurrent_clients() {
    let Ok(engine) = PjrtEngine::start(artifacts_dir()) else {
        eprintln!("skipped: artifacts/xla unavailable; run `make artifacts`");
        return;
    };
    let handle = engine.handle();
    let mut threads = Vec::new();
    for t in 0..4u64 {
        let h = handle.clone();
        threads.push(std::thread::spawn(move || {
            let mut rng = Xoshiro256::new(100 + t);
            let r = Mat::gaussian(64, 256, 1.0, &mut rng);
            let a = Mat::gaussian(256, 256, 1.0, &mut rng);
            let got = h.project("proj_xla", r.clone(), a.clone()).unwrap();
            let want = matmul(&r, &a);
            assert!(rel_frobenius_error(&want, &got) < 1e-5);
        }));
    }
    for t in threads {
        t.join().unwrap();
    }
}

#[test]
fn unknown_artifact_is_clean_error() {
    let Some(reg) = registry() else { return };
    let err = reg.run("nonexistent_op", &[]).unwrap_err();
    assert!(err.to_string().contains("unknown artifact"));
}

#[test]
fn shape_mismatch_is_clean_error() {
    let Some(reg) = registry() else { return };
    let bad = Mat::zeros(3, 3);
    let err = reg.run("proj_xla_m64_n256", &[&bad, &bad]).unwrap_err();
    assert!(err.to_string().contains("manifest wants"));
}
