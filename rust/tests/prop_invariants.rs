//! Property-based invariants (testkit, our proptest-lite): coordinator
//! routing/batching/state invariants plus the algebraic substrate laws
//! they depend on.

use std::sync::Arc;
use std::time::Duration;

use photonic_randnla::coordinator::{
    Availability, BatchConfig, Coordinator, CoordinatorConfig, Job, Policy, Router,
};
use photonic_randnla::linalg::{self, Mat};
use photonic_randnla::opu::{encoding, NoiseModel};
use photonic_randnla::rng::Xoshiro256;
use photonic_randnla::testkit::check;

#[test]
fn prop_router_respects_availability() {
    check("router never picks an absent device", 200, |g| {
        let avail = Availability {
            opu: g.bool(),
            pjrt: g.bool(),
            pjrt_max: (g.usize(16, 2048), g.usize(16, 4096)),
            opu_max_n: g.usize(1024, 1 << 20),
            opu_max_m: g.usize(1024, 1 << 20),
        };
        let r = Router::new(Policy::Auto, avail);
        let m = g.usize(8, 4096);
        let n = g.usize(8, 1 << 15);
        let k = g.usize(1, 512);
        let route = r.route(m, n, k);
        match route.device {
            photonic_randnla::coordinator::Device::Opu if !avail.opu => {
                Err(format!("routed to absent OPU: m={m} n={n}"))
            }
            photonic_randnla::coordinator::Device::Pjrt
                if !avail.pjrt || m > avail.pjrt_max.0 || n > avail.pjrt_max.1 =>
            {
                Err(format!("routed to unfit PJRT: m={m} n={n} max={:?}", avail.pjrt_max))
            }
            _ => Ok(()),
        }
    });
}

#[test]
fn prop_router_predictions_positive_and_monotone_in_k() {
    check("predicted latency positive, nondecreasing in batch", 100, |g| {
        let r = Router::new(Policy::Auto, Availability::default());
        let m = g.usize(8, 512);
        let n = g.usize(8, 1024);
        let k1 = g.usize(1, 64);
        let k2 = k1 + g.usize(1, 64);
        let r1 = r.route(m, n, k1);
        let r2 = r.route(m, n, k2);
        if r1.predicted_ms <= 0.0 {
            return Err(format!("non-positive prediction {}", r1.predicted_ms));
        }
        // Same device => more columns cannot be predicted cheaper.
        if r1.device == r2.device && r2.predicted_ms + 1e-9 < r1.predicted_ms {
            return Err(format!(
                "k {k1}->{k2} got cheaper: {} -> {}",
                r1.predicted_ms, r2.predicted_ms
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_batched_projection_equals_individual() {
    // The batcher invariant: merging requests never changes any result.
    let coord = Coordinator::start(CoordinatorConfig {
        workers: 4,
        policy: Policy::ForceHost,
        batch: BatchConfig {
            max_wait: Duration::from_micros(2000),
            noise: NoiseModel::ideal(),
            ..Default::default()
        },
        ..Default::default()
    })
    .unwrap();
    let coord = Arc::new(coord);

    check("batching preserves per-request results", 12, |g| {
        let n = 16 * g.usize(1, 4);
        let m = 8 * g.usize(1, 2);
        let reqs: Vec<Mat> = (0..g.usize(2, 6))
            .map(|_| {
                let mut rng = g.rng();
                Mat::gaussian(n, g.usize(1, 5), 1.0, &mut rng)
            })
            .collect();
        // Submit concurrently (they will merge), then sequentially.
        let tickets: Vec<_> = reqs
            .iter()
            .map(|x| coord.submit(Job::Projection { data: x.clone(), m }))
            .collect();
        let merged: Vec<Mat> = tickets
            .into_iter()
            .map(|t| t.wait().unwrap().payload.matrix().unwrap().clone())
            .collect();
        for (x, got) in reqs.iter().zip(&merged) {
            let again = coord
                .run(Job::Projection { data: x.clone(), m })
                .unwrap();
            let again = again.payload.matrix().unwrap().clone();
            if linalg::rel_frobenius_error(&again, got) > 1e-12 {
                return Err(format!("batch result differs at n={n} m={m}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_bitplane_roundtrip() {
    check("bitplane encode/decode roundtrip within half LSB", 60, |g| {
        let mut rng = g.rng();
        let rows = g.usize(1, 40);
        let cols = g.usize(1, 6);
        let bits = g.usize(2, 12);
        let x = Mat::gaussian(rows, cols, g.f64(0.1, 5.0), &mut rng);
        let bp = encoding::encode(&x, bits);
        let xq = encoding::decode(&bp);
        for j in 0..cols {
            let lsb = bp.scales[j];
            for i in 0..rows {
                let e = (x.at(i, j) - xq.at(i, j)).abs();
                if e > 0.5 * lsb + 1e-9 {
                    return Err(format!("err {e} > lsb/2 {lsb} at bits={bits}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_pad_crop_roundtrip() {
    check("pad then crop is identity", 100, |g| {
        let mut rng = g.rng();
        let r = g.usize(1, 30);
        let c = g.usize(1, 30);
        let m = Mat::gaussian(r, c, 1.0, &mut rng);
        let p = m.pad(r + g.usize(0, 20), c + g.usize(0, 20));
        if p.crop(r, c) != m {
            return Err(format!("roundtrip failed at {r}x{c}"));
        }
        Ok(())
    });
}

#[test]
fn prop_qr_reconstructs() {
    check("thin QR: A = QR and Q orthonormal", 30, |g| {
        let mut rng = g.rng();
        let n = g.usize(1, 12);
        let m = n + g.usize(0, 20);
        let a = Mat::gaussian(m, n, 1.0, &mut rng);
        let qr = linalg::thin_qr(&a);
        let rec = linalg::matmul(&qr.q, &qr.r);
        if linalg::rel_frobenius_error(&a, &rec) > 1e-9 {
            return Err(format!("A != QR at {m}x{n}"));
        }
        let qtq = linalg::matmul_tn(&qr.q, &qr.q);
        if linalg::rel_frobenius_error(&Mat::eye(n), &qtq) > 1e-9 {
            return Err(format!("Q^T Q != I at {m}x{n}"));
        }
        Ok(())
    });
}

#[test]
fn prop_svd_frobenius_identity() {
    check("sum sigma^2 == ||A||_F^2", 25, |g| {
        let mut rng = g.rng();
        let r = g.usize(1, 14);
        let c = g.usize(1, 14);
        let a = Mat::gaussian(r, c, 1.0, &mut rng);
        let s = linalg::svd(&a).s;
        let sum: f64 = s.iter().map(|x| x * x).sum();
        let fro2 = linalg::frobenius(&a).powi(2);
        if (sum - fro2).abs() > 1e-7 * fro2.max(1.0) {
            return Err(format!("{sum} vs {fro2} at {r}x{c}"));
        }
        Ok(())
    });
}

#[test]
fn prop_graph_triangle_trace_identity() {
    check("Tr(A^3) == 6 * exact triangle count", 20, |g| {
        let n = g.usize(4, 40);
        let p = g.f64(0.05, 0.5);
        let seed = g.u64(0..=u64::MAX);
        let graph = photonic_randnla::graph::generators::erdos_renyi(n, p, seed);
        let dense = linalg::trace_cubed(&graph.adjacency());
        let exact = 6.0 * graph.exact_triangles() as f64;
        if (dense - exact).abs() > 1e-6 {
            return Err(format!("n={n} p={p}: {dense} vs {exact}"));
        }
        Ok(())
    });
}

#[test]
fn prop_philox_parallel_partition_invariance() {
    // The OPU's reproducibility bedrock: any partition of the index space
    // generates identical values.
    check("philox random access == streaming", 40, |g| {
        let seed = g.u64(0..=u64::MAX);
        let m = g.usize(1, 8);
        let n = g.usize(1, 64);
        let tm = photonic_randnla::opu::TransmissionMatrix::new(seed, m, n);
        let i = g.usize(0, m - 1);
        let j = g.usize(0, n - 1);
        let mut re = vec![0.0; n];
        let mut im = vec![0.0; n];
        tm.row_into(i, &mut re, &mut im);
        let (er, ei) = tm.entry(i, j);
        if er != re[j] || ei != im[j] {
            return Err(format!("mismatch at ({i},{j}) seed {seed}"));
        }
        Ok(())
    });
}

#[test]
fn prop_sketch_scale_equivariance() {
    check("G(c*x) == c * G(x) for the digital sketcher", 40, |g| {
        let mut rng = g.rng();
        let n = g.usize(2, 48);
        let m = g.usize(1, 24);
        let c = g.f64(-3.0, 3.0);
        let s = photonic_randnla::randnla::DigitalSketcher::new(m, n, g.u64(0..=u64::MAX));
        use photonic_randnla::randnla::Sketcher;
        let x = Mat::gaussian(n, 2, 1.0, &mut rng);
        let lhs = s.project(&x.scale(c));
        let rhs = s.project(&x).scale(c);
        if linalg::rel_frobenius_error(&rhs, &lhs) > 1e-10 {
            return Err(format!("scale equivariance broken: c={c} n={n} m={m}"));
        }
        Ok(())
    });
}
