//! End-to-end coverage for the telemetry plane (ISSUE 10):
//!
//! - **span assembly**: a cache-hit job's span carries zero `projected`
//!   passes and `cache_hit = Some(true)`; the cold job that parked the
//!   sketch shows the real device pass;
//! - **exposition validity**: [`TelemetryRegistry::render`] emits
//!   parseable Prometheus text — legal metric names, a `# TYPE` comment
//!   ahead of every family, monotone cumulative `_bucket` series ending
//!   in `+Inf`, finite sample values;
//! - **drift auditing**: a seeded ForceHost workload populates the
//!   (host, f64, dense) perfmodel route and its drift-ratio gauge;
//! - **cluster stitching**: worker-side ingest/seal spans journaled on
//!   the wire plane land in the coordinator's stage histograms;
//! - **trace-out**: `trace_out` streams loadable Chrome `trace_event`
//!   JSON.

use std::time::{Duration, Instant};

use photonic_randnla::coordinator::{
    BatchConfig, Coordinator, CoordinatorConfig, Device, EventLog, Job, JobSpan, JobSpec,
    OperandRef, Policy, PoolConfig, Precision, QosClass, StreamId, StreamOpts, SubmitOptions,
    TelemetryRegistry, TenantRegistry, TraceEstimator,
};
use photonic_randnla::linalg::Mat;
use photonic_randnla::net::{WireServer, WorkerConfig, WorkerNode};
use photonic_randnla::opu::NoiseModel;
use photonic_randnla::perfmodel::SketchKind;
use photonic_randnla::rng::Xoshiro256;
use photonic_randnla::testkit::ephemeral_loopback;
use photonic_randnla::workload::psd_matrix;

fn telemetry_coordinator(cache_quota: usize) -> Coordinator {
    Coordinator::start(CoordinatorConfig {
        workers: 2,
        policy: Policy::ForceHost,
        batch: BatchConfig {
            noise: NoiseModel::ideal(),
            max_wait: Duration::from_micros(50),
            ..Default::default()
        },
        pool: PoolConfig { pjrt_replicas: 0, ..Default::default() },
        cache_quota,
        telemetry: true,
        ..Default::default()
    })
    .expect("coordinator start")
}

/// Spans assemble asynchronously (the registry is a projector); sync the
/// log and poll — the terminal event may land after `Ticket::wait`
/// returns.
fn wait_span(reg: &TelemetryRegistry, events: &EventLog, job: u64) -> JobSpan {
    let t0 = Instant::now();
    loop {
        events.sync();
        if let Some(s) = reg.span(job) {
            return s;
        }
        assert!(t0.elapsed() < Duration::from_secs(10), "span {job} never assembled");
        std::thread::sleep(Duration::from_millis(2));
    }
}

#[test]
fn cache_hit_span_has_zero_projected_passes() {
    let c = telemetry_coordinator(1 << 20);
    let reg = c.telemetry().expect("telemetry plane armed").clone();
    let id = c.upload(psd_matrix(24, 48, 1)).unwrap();
    let spec = || JobSpec::Trace {
        a: OperandRef::Handle(id),
        m: 12,
        estimator: TraceEstimator::Hutchinson,
    };

    // Cold: misses the cache, takes a real device pass.
    let t1 = c.submit_spec(spec(), SubmitOptions::default()).unwrap();
    let job1 = t1.id;
    t1.wait().unwrap();
    // Warm: same spec, same operand — must be served from the cache.
    let t2 = c.submit_spec(spec(), SubmitOptions::default()).unwrap();
    let job2 = t2.id;
    t2.wait().unwrap();
    assert_eq!(c.metrics.cache_hits.load(std::sync::atomic::Ordering::Relaxed), 1);

    let cold = wait_span(&reg, c.events(), job1);
    assert_eq!(cold.cache_hit, Some(false), "cold span: {cold:?}");
    assert!(!cold.projected.is_empty(), "cold job must record a device pass: {cold:?}");
    for p in &cold.projected {
        assert_eq!(p.arm, Device::Host);
        assert!(p.cols > 0);
    }
    assert!(cold.total_us > 0);

    let warm = wait_span(&reg, c.events(), job2);
    assert_eq!(warm.cache_hit, Some(true), "warm span: {warm:?}");
    assert!(
        warm.projected.is_empty(),
        "cache-hit job did zero device work yet recorded passes: {warm:?}"
    );

    assert!(reg.spans_completed() >= 2);
    c.shutdown();
}

// ---------------------------------------------------------------------------
// Exposition format
// ---------------------------------------------------------------------------

fn legal_name(name: &str) -> bool {
    !name.is_empty()
        && !name.starts_with(|c: char| c.is_ascii_digit())
        && name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
}

/// One parsed sample line: (family name, label pairs, value).
fn parse_sample(line: &str) -> (String, Vec<(String, String)>, f64) {
    let (series, value) = line.rsplit_once(' ').unwrap_or_else(|| panic!("no value: {line}"));
    let value: f64 = value.parse().unwrap_or_else(|_| {
        if value == "+Inf" {
            f64::INFINITY
        } else {
            panic!("unparseable value in {line:?}")
        }
    });
    let (name, labels) = match series.split_once('{') {
        None => (series.to_string(), Vec::new()),
        Some((name, rest)) => {
            let body = rest.strip_suffix('}').unwrap_or_else(|| panic!("unclosed labels: {line}"));
            let mut pairs = Vec::new();
            // Label values in this plane never contain escaped quotes or
            // commas (tenant/worker names are identifiers + addresses),
            // so a flat split is an honest parser for the test corpus.
            for pair in body.split(',') {
                let (k, v) = pair.split_once('=').unwrap_or_else(|| panic!("bad label: {line}"));
                let v = v.strip_prefix('"').and_then(|v| v.strip_suffix('"'));
                pairs.push((k.to_string(), v.unwrap_or_else(|| panic!("unquoted: {line}")).to_string()));
            }
            (name.to_string(), pairs)
        }
    };
    (name, labels, value)
}

/// The family a sample belongs to for `# TYPE` purposes: histogram
/// samples hang off the base name.
fn base_family(name: &str) -> &str {
    name.strip_suffix("_bucket")
        .or_else(|| name.strip_suffix("_sum"))
        .or_else(|| name.strip_suffix("_count"))
        .unwrap_or(name)
}

#[test]
fn exposition_is_valid_prometheus_text() {
    let c = telemetry_coordinator(1 << 20);
    let mut rng = Xoshiro256::new(41);
    // A workload wide enough to light up every family: projections
    // (device histograms + drift), a cached trace pair (probe counters),
    // and a queued burst (queue-wait reservoirs).
    let tickets: Vec<_> = (0..8)
        .map(|_| c.submit(Job::Projection { data: Mat::gaussian(48, 2, 1.0, &mut rng), m: 16 }))
        .collect();
    for t in tickets {
        t.wait().unwrap();
    }
    let id = c.upload(psd_matrix(24, 48, 2)).unwrap();
    let spec = || JobSpec::Trace {
        a: OperandRef::Handle(id),
        m: 12,
        estimator: TraceEstimator::Hutchinson,
    };
    c.run_spec(spec(), SubmitOptions::default()).unwrap();
    c.run_spec(spec(), SubmitOptions::default()).unwrap();
    c.events().sync();

    let text = c.telemetry().unwrap().render();
    let mut typed: std::collections::HashSet<String> = std::collections::HashSet::new();
    let mut samples = Vec::new();
    for line in text.lines() {
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.split(' ');
            let fam = it.next().unwrap();
            let kind = it.next().unwrap_or_else(|| panic!("TYPE without kind: {line}"));
            assert!(legal_name(fam), "illegal family name {fam:?}");
            assert!(
                matches!(kind, "counter" | "gauge" | "histogram"),
                "unknown metric type in {line:?}"
            );
            assert!(typed.insert(fam.to_string()), "duplicate # TYPE for {fam}");
            continue;
        }
        if line.starts_with('#') {
            assert!(line.starts_with("# HELP "), "unknown comment {line:?}");
            continue;
        }
        samples.push(parse_sample(line));
    }
    assert!(!samples.is_empty(), "empty exposition");
    for (name, labels, value) in &samples {
        assert!(legal_name(name), "illegal sample name {name:?}");
        assert!(
            typed.contains(base_family(name)),
            "sample {name} has no preceding # TYPE"
        );
        for (k, _) in labels {
            assert!(legal_name(k), "illegal label name {k:?} on {name}");
        }
        assert!(value.is_infinite() || value.is_finite(), "NaN sample on {name}");
        assert!(!value.is_nan(), "NaN sample on {name}");
    }

    // Cumulative histogram buckets: per (name, non-le labels) the counts
    // are monotone nondecreasing in `le` order and the series ends +Inf.
    let mut series: std::collections::HashMap<String, Vec<(String, f64)>> =
        std::collections::HashMap::new();
    for (name, labels, value) in &samples {
        if !name.ends_with("_bucket") {
            continue;
        }
        let le = labels.iter().find(|(k, _)| k == "le").expect("bucket without le").1.clone();
        let mut key = name.clone();
        for (k, v) in labels {
            if k != "le" {
                key.push_str(&format!("|{k}={v}"));
            }
        }
        series.entry(key).or_default().push((le, *value));
    }
    assert!(!series.is_empty(), "no histogram series rendered");
    for (key, buckets) in &series {
        // Exposition order is ascending-le already; hold it to that.
        let mut prev = 0.0f64;
        for (_, count) in buckets {
            assert!(*count >= prev, "{key}: bucket counts regressed");
            prev = *count;
        }
        assert_eq!(buckets.last().unwrap().0, "+Inf", "{key}: no +Inf bucket");
    }

    // The families the acceptance bar names must all be present.
    for fam in [
        "photon_jobs_submitted_total",
        "photon_cache_hits_total",
        "photon_request_latency_us",
        "photon_queue_wait_us",
        "photon_spans_completed_total",
        "photon_stage_duration_us",
        "photon_perfmodel_batches_total",
        "photon_perfmodel_drift_ratio",
    ] {
        assert!(typed.contains(fam), "family {fam} missing from exposition:\n{text}");
    }
    c.shutdown();
}

#[test]
fn drift_auditor_prices_the_host_route() {
    let c = telemetry_coordinator(0);
    let reg = c.telemetry().unwrap().clone();
    let mut rng = Xoshiro256::new(43);
    let tickets: Vec<_> = (0..6)
        .map(|_| c.submit(Job::Projection { data: Mat::gaussian(64, 2, 1.0, &mut rng), m: 24 }))
        .collect();
    for t in tickets {
        t.wait().unwrap();
    }
    c.events().sync();

    // ForceHost + dense operator: every batch lands on one route, and
    // the host model's fixed overhead guarantees a nonzero prediction,
    // so the ratio is well-defined.
    let ratio = reg
        .drift()
        .ratio(Device::Host, Precision::F64, SketchKind::Dense)
        .expect("host route never audited");
    assert!(ratio.is_finite() && ratio >= 0.0, "nonsense drift ratio {ratio}");
    assert!(
        reg.drift().ratio(Device::Opu, Precision::F64, SketchKind::Dense).is_none(),
        "phantom route audited"
    );
    let text = reg.render();
    assert!(
        text.contains(r#"photon_perfmodel_drift_ratio{arm="host",tier="f64",sketch="dense"}"#),
        "drift gauge missing:\n{text}"
    );
    c.shutdown();
}

// ---------------------------------------------------------------------------
// Cluster stitching
// ---------------------------------------------------------------------------

#[test]
fn cluster_stream_stitches_worker_spans_into_stage_histograms() {
    let tenants = TenantRegistry::new().add("w", "wtok", usize::MAX, QosClass::Batch);
    let srv = WireServer::start(telemetry_coordinator(0), &ephemeral_loopback(), tenants)
        .expect("server start");
    let workers: Vec<WorkerNode> = (0..2)
        .map(|i| {
            WorkerNode::connect(&srv.addr().to_string(), "wtok", WorkerConfig::default())
                .unwrap_or_else(|e| panic!("worker {i} join: {e}"))
        })
        .collect();
    let c = srv.coordinator();
    let t0 = Instant::now();
    while c.cluster().worker_count() < 2 {
        assert!(t0.elapsed() < Duration::from_secs(10), "workers never registered");
        std::thread::sleep(Duration::from_millis(5));
    }

    let mut rng = Xoshiro256::new(47);
    let a = Mat::gaussian(64, 8, 1.0, &mut rng);
    let opts = StreamOpts { chunk_rows: Some(8), sketch_m: 16, fd_rank: 8, range_cap: 4 };
    let id: StreamId = c.begin_stream(a.rows, a.cols, opts).unwrap();
    let mut r0 = 0usize;
    while r0 < a.rows {
        let r1 = (r0 + 8).min(a.rows);
        c.append_stream(id, &Mat::from_fn(r1 - r0, a.cols, |i, j| a.at(r0 + i, j))).unwrap();
        r0 = r1;
    }
    c.seal_stream(id).unwrap();

    // Worker slot summaries arrive on server session threads; poll the
    // exposition until every wire-plane stage shows up.
    let reg = c.telemetry().unwrap();
    let t0 = Instant::now();
    loop {
        c.events().sync();
        let text = reg.render();
        let stitched = [r#"stage="worker_ingest""#, r#"stage="worker_seal""#, r#"stage="stream_seal""#]
            .iter()
            .all(|s| text.contains(s));
        if stitched {
            break;
        }
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "worker spans never reached the registry:\n{text}"
        );
        std::thread::sleep(Duration::from_millis(5));
    }
    assert!(c.free_stream(id));
    drop(workers);
    srv.shutdown();
}

// ---------------------------------------------------------------------------
// Chrome trace output
// ---------------------------------------------------------------------------

#[test]
fn trace_out_streams_loadable_chrome_json() {
    let path = std::path::Path::new(env!("CARGO_TARGET_TMPDIR")).join("telemetry_plane_trace.json");
    std::fs::remove_file(&path).ok();
    let c = Coordinator::start(CoordinatorConfig {
        workers: 2,
        policy: Policy::ForceHost,
        batch: BatchConfig {
            noise: NoiseModel::ideal(),
            max_wait: Duration::from_micros(50),
            ..Default::default()
        },
        pool: PoolConfig { pjrt_replicas: 0, ..Default::default() },
        telemetry: true,
        trace_out: Some(path.clone()),
        ..Default::default()
    })
    .expect("coordinator start");
    let mut rng = Xoshiro256::new(53);
    for _ in 0..3 {
        c.run(Job::Projection { data: Mat::gaussian(48, 2, 1.0, &mut rng), m: 16 }).unwrap();
    }
    c.events().sync();
    c.shutdown(); // closes the JSON array via finish_trace

    let text = std::fs::read_to_string(&path).expect("trace file written");
    std::fs::remove_file(&path).ok();
    let t = text.trim();
    assert!(t.starts_with('[') && t.ends_with(']'), "not a JSON array:\n{t}");
    assert!(t.contains(r#""ph":"X""#), "no complete slices:\n{t}");
    assert!(t.contains(r#""pid":1"#) && t.contains(r#""ts":"#) && t.contains(r#""dur":"#));
    // Balanced braces => structurally sound slice objects.
    assert_eq!(t.matches('{').count(), t.matches('}').count(), "unbalanced JSON:\n{t}");
}
