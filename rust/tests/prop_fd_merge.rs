//! Properties of the scale-out plane's summary algebra.
//!
//! The cluster plane only works because its summaries are mergeable by
//! construction; these properties pin the algebra down over random
//! shapes, seeds, splits, and chunk sizes:
//!
//! 1. Frequent Directions merging is order-insensitive *within the
//!    composed bound*: however a row-partition's FD parts are merged,
//!    the merged sketch's measured Σδ dominates the true Gram error and
//!    stays under the a-priori `‖A‖²_F/(ℓ−k)` guarantee with the
//!    *composed* δ accounting (Ghashami et al. 2016, Thm. 1.2).
//! 2. The same composed guarantee survives the tree-shaped reduction
//!    (`tree_reduce_fd`) at any arity.
//! 3. Counter-sketch accumulators (`S·A`, `Yᵀ`, `‖A‖²_F`) reduce
//!    bit-identically whatever the reduction tree's arity and however
//!    the FD side was split — the canonical ascending-slot fold is a
//!    fixed f64 association, so 2-way and 4-way trees cannot move a bit.

use std::ops::Range;

use photonic_randnla::coordinator::{
    plan_slots, reduce_parts, tree_reduce_fd, Device, FdPart, PartSummary,
};
use photonic_randnla::linalg::{matmul_tn, spectral_norm, Mat};
use photonic_randnla::randnla::{CounterSketcher, FrequentDirections, RowBlockSketcher, Sketcher};
use photonic_randnla::testkit::{check, Gen};

/// Random contiguous partition of `0..rows` into `parts` nonempty ranges.
fn random_splits(g: &mut Gen, rows: usize, parts: usize) -> Vec<Range<usize>> {
    let mut cuts = vec![0usize, rows];
    while cuts.len() < parts + 1 {
        let c = g.usize(1, rows - 1);
        if !cuts.contains(&c) {
            cuts.push(c);
        }
    }
    cuts.sort_unstable();
    cuts.windows(2).map(|w| w[0]..w[1]).collect()
}

/// Per-partition FD summaries of `a`, each fed row ranges in chunks.
fn fd_parts(a: &Mat, splits: &[Range<usize>], ell: usize, chunk: usize) -> Vec<FdPart> {
    splits
        .iter()
        .map(|r| {
            let mut fd = FrequentDirections::new(ell, a.cols);
            let mut r0 = r.start;
            while r0 < r.end {
                let r1 = (r0 + chunk).min(r.end);
                fd.insert(&Mat::from_fn(r1 - r0, a.cols, |i, j| a.at(r0 + i, j)));
                r0 = r1;
            }
            fd.compress();
            FdPart { r0: r.start, fd: fd.sketch(), bound: fd.bound(), fro2: fd.fro2() }
        })
        .collect()
}

/// `‖AᵀA − BᵀB‖₂` by power iteration.
fn gram_error(a: &Mat, b: &Mat) -> f64 {
    spectral_norm(&matmul_tn(a, a).sub(&matmul_tn(b, b)), 300, 7)
}

/// Per-slot counter-sketch summaries of `a`, the way a worker computes
/// them: chunk-ordered absolute-offset partials, exact per-slot fro2.
fn slot_parts(a: &Mat, chunk: usize, m: usize, cap: usize, seed: u64) -> Vec<PartSummary> {
    let s_op = CounterSketcher::new(m, a.rows, seed);
    let omega = CounterSketcher::new(cap, a.cols, seed ^ 1);
    plan_slots(a.rows, chunk)
        .into_iter()
        .map(|r| {
            let mut sa = Mat::zeros(m, a.cols);
            let mut yt = Mat::zeros(cap, r.len());
            let mut fro2 = 0.0f64;
            let mut chunks = 0u64;
            let mut r0 = r.start;
            while r0 < r.end {
                let r1 = (r0 + chunk).min(r.end);
                let block = Mat::from_fn(r1 - r0, a.cols, |i, j| a.at(r0 + i, j));
                let partial = RowBlockSketcher::project_rows(&s_op, r0..r1, &block);
                for (dst, v) in sa.data.iter_mut().zip(&partial.data) {
                    *dst += v;
                }
                let y = Sketcher::project(&omega, &block.transpose());
                for i in 0..cap {
                    yt.row_mut(i)[r0 - r.start..r1 - r.start].copy_from_slice(y.row(i));
                }
                fro2 += block.data.iter().map(|v| v * v).sum::<f64>();
                chunks += 1;
                r0 = r1;
            }
            PartSummary {
                r0: r.start,
                r1: r.end,
                sa,
                yt,
                fro2,
                chunks,
                arm: Some(Device::Host),
                y_arm: Some(Device::Host),
            }
        })
        .collect()
}

#[test]
fn fd_merge_is_order_insensitive_within_the_composed_bound() {
    check("fd merge order-insensitive", 40, |g| {
        let rows = g.usize(24, 80);
        let cols = g.usize(3, 10);
        let ell = g.usize(cols.min(6), 10);
        let k = ell / 2;
        let chunk = g.usize(1, rows);
        let nparts = g.usize(2, 5.min(rows - 1));
        let mut rng = g.rng();
        let a = Mat::gaussian(rows, cols, 1.0, &mut rng);
        let parts = fd_parts(&a, &random_splits(g, rows, nparts), ell, chunk);

        // Merge ascending, then in a rotated order: both must satisfy
        // the composed accounting.
        let rot = g.usize(0, parts.len() - 1);
        for (label, order) in [
            ("ascending", (0..parts.len()).collect::<Vec<_>>()),
            ("rotated", (0..parts.len()).map(|i| (i + rot) % parts.len()).collect()),
        ] {
            let mut fd = FrequentDirections::new(ell, cols);
            for &i in &order {
                fd.merge(&parts[i].fd, parts[i].bound, parts[i].fro2);
            }
            fd.compress();
            let err = gram_error(&a, &fd.sketch());
            let bound = fd.bound();
            if err > bound * (1.0 + 1e-9) + 1e-12 {
                return Err(format!("{label}: gram error {err} above composed bound {bound}"));
            }
            if bound > fd.fro2() / (ell - k) as f64 + 1e-12 {
                return Err(format!(
                    "{label}: composed bound {bound} above guarantee {}",
                    fd.fro2() / (ell - k) as f64
                ));
            }
            let fro2_true: f64 = a.data.iter().map(|v| v * v).sum();
            if (fd.fro2() - fro2_true).abs() > 1e-6 * fro2_true.max(1.0) {
                return Err(format!("{label}: merged fro2 {} != {fro2_true}", fd.fro2()));
            }
        }
        Ok(())
    });
}

#[test]
fn tree_reduction_keeps_the_composed_guarantee_at_any_arity() {
    check("tree reduce composed guarantee", 30, |g| {
        let rows = g.usize(24, 80);
        let cols = g.usize(3, 8);
        let ell = g.usize(cols.min(5), 9);
        let k = ell / 2;
        let nparts = g.usize(2, 6.min(rows - 1));
        let arity = g.usize(2, 4);
        let mut rng = g.rng();
        let a = Mat::gaussian(rows, cols, 1.0, &mut rng);
        let parts = fd_parts(&a, &random_splits(g, rows, nparts), ell, g.usize(1, rows));
        let fd = tree_reduce_fd(&parts, ell, cols, arity);
        let err = gram_error(&a, &fd.sketch());
        if err > fd.bound() * (1.0 + 1e-9) + 1e-12 {
            return Err(format!("arity {arity}: error {err} above bound {}", fd.bound()));
        }
        if fd.bound() > fd.fro2() / (ell - k) as f64 + 1e-12 {
            return Err(format!("arity {arity}: bound {} above guarantee", fd.bound()));
        }
        Ok(())
    });
}

#[test]
fn counter_sketch_reduction_is_bit_identical_across_tree_arity() {
    check("accumulator reduction arity-invariant", 30, |g| {
        let chunk = *g.pick(&[4usize, 8, 16]);
        let rows = chunk * g.usize(2, 10);
        let cols = g.usize(3, 8);
        let (m, cap, ell) = (g.usize(4, 8), g.usize(2, 4), g.usize(cols.min(4), 8));
        let seed = g.u64(0..=u64::MAX);
        let mut rng = g.rng();
        let a = Mat::gaussian(rows, cols, 1.0, &mut rng);
        let parts = slot_parts(&a, chunk, m, cap, seed);
        let half = rows / 2 / chunk * chunk;
        let halves = fd_parts(&a, &[0..half.max(chunk), half.max(chunk)..rows], ell, chunk);
        let quarters = fd_parts(&a, &random_splits(g, rows, 4.min(rows - 1)), ell, chunk);
        let r2 = reduce_parts(rows, cols, m, cap, ell, parts.clone(), halves, 2)
            .map_err(|e| e.to_string())?;
        let r4 = reduce_parts(rows, cols, m, cap, ell, parts, quarters, 4)
            .map_err(|e| e.to_string())?;
        if r2.sa != r4.sa {
            return Err("S·A moved bits across tree arity".into());
        }
        if r2.yt != r4.yt {
            return Err("Yᵀ moved bits across tree arity".into());
        }
        if r2.fro2.to_bits() != r4.fro2.to_bits() {
            return Err(format!("fro2 bits differ: {} vs {}", r2.fro2, r4.fro2));
        }
        // And the merged accumulator is the unpartitioned operator apply.
        let s_op = CounterSketcher::new(m, rows, seed);
        let truth = Sketcher::project(&s_op, &a);
        let drift: f64 = truth
            .data
            .iter()
            .zip(&r2.sa.data)
            .map(|(t, s)| (t - s).abs())
            .fold(0.0, f64::max);
        if drift > 1e-9 {
            return Err(format!("merged S·A drifted {drift} from the direct apply"));
        }
        Ok(())
    });
}
