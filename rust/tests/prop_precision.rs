//! Property tests for the mixed-precision projection arms (testkit, our
//! proptest-lite), mirroring tests/prop_sketch_stats.rs:
//!
//! - per-tier JL distortion: E[||Sx||^2 / m] = ||x||^2 over Philox
//!   seeds at every arithmetic tier (the statistical contract survives
//!   f32/bf16 rounding);
//! - per-tier operator scale: E[S^T S] = m I over seeds, measured on
//!   the tier's own arithmetic (S applied to the identity);
//! - compensated f32 beats the naive all-f32 k-loop on ill-conditioned
//!   accumulations (the KC-blocked promotion is what buys the tier its
//!   documented bound);
//! - seeded RandSVD spectra at Bf16 through the coordinator stay within
//!   the documented `Precision::Bf16.tier_tol()` of the f64 run;
//! - shard cells are bit-identical to the unsharded apply at every
//!   tier, for 1-4 output shards (the batcher's per-tier
//!   bit-reproducibility contract).

use photonic_randnla::coordinator::{
    BatchConfig, Coordinator, CoordinatorConfig, JobSpec, OperandRef, Policy, PoolConfig,
    SubmitOptions,
};
use photonic_randnla::linalg::{
    matmul, matmul_f32, matmul_f32_naive, matmul_tn, rel_frobenius_error, Mat, Precision,
};
use photonic_randnla::opu::NoiseModel;
use photonic_randnla::parallel::split_ranges;
use photonic_randnla::randnla::structured::{SparseSignSketcher, SrhtSketcher};
use photonic_randnla::testkit::check;
use photonic_randnla::workload::{matrix_with_spectrum, Spectrum};

const TIERS: [Precision; 3] = [Precision::F64, Precision::F32, Precision::Bf16];

#[test]
fn prop_srht_jl_norm_preservation_per_tier() {
    // JL over Philox seeds at every tier: tier rounding (<= 1e-2
    // relative per product) is far inside the 0.25 statistical band the
    // f64 suite already allows.
    check("SRHT JL norm preservation per tier", 8, |g| {
        let n = g.usize(8, 120);
        let m = g.usize(8, 64);
        let mut rng = g.rng();
        let x = Mat::gaussian(n, 1, 1.0, &mut rng);
        let x2: f64 = x.data.iter().map(|v| v * v).sum();
        let trials = 64u64;
        let base = g.u64(0..=u64::MAX / 2);
        for tier in TIERS {
            let mut acc = 0.0;
            for t in 0..trials {
                let s = SrhtSketcher::new(m, n, base + t);
                let y = s.project_block_lowp(0..m, 0..n, &x, tier);
                acc += y.data.iter().map(|v| v * v).sum::<f64>() / m as f64;
            }
            let mean = acc / trials as f64;
            let rel = (mean - x2).abs() / x2;
            if rel > 0.25 {
                return Err(format!(
                    "JL violated at n={n} m={m} tier={}: {mean} vs {x2} ({rel})",
                    tier.label()
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_expected_sts_is_m_identity_per_tier() {
    // E[S^T S] = m I, measured on the tier's own arithmetic: apply S to
    // the identity at the tier, form S^T S in f64, average over seeds.
    check("E[S^T S] = m I per tier", 6, |g| {
        let n = g.usize(6, 24);
        let m = g.usize(8, 48);
        let trials = 64u64;
        let base = g.u64(0..=u64::MAX / 2);
        let eye = Mat::eye(n);
        for tier in TIERS {
            let mut acc = Mat::zeros(n, n);
            for t in 0..trials {
                let s = SrhtSketcher::new(m, n, base + t);
                let y = s.project_block_lowp(0..m, 0..n, &eye, tier);
                acc = acc.add(&matmul_tn(&y, &y));
            }
            let mean = acc.scale(1.0 / trials as f64);
            let want = eye.scale(m as f64);
            let rel = rel_frobenius_error(&want, &mean);
            if rel > 0.35 {
                return Err(format!(
                    "E[S^T S] off m*I at n={n} m={m} tier={}: rel {rel}",
                    tier.label()
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_compensated_f32_beats_naive_on_ill_conditioned_operands() {
    // Entries spanning four orders of magnitude over a long k: the
    // naive all-f32 running sum absorbs small terms, the KC-blocked
    // promotion restarts the f32 partial and keeps the error bounded by
    // the block length.
    check("compensated f32 beats naive f32", 8, |g| {
        let k = g.usize(1024, 4096);
        let rows = g.usize(2, 4);
        let cols = g.usize(2, 5);
        let mut rng = g.rng();
        let mut a = Mat::gaussian(rows, k, 1.0, &mut rng);
        for i in 0..rows {
            for j in 0..k {
                *a.at_mut(i, j) *= 10f64.powi((j % 5) as i32);
            }
        }
        let b = Mat::gaussian(k, cols, 1.0, &mut rng);
        let exact = matmul(&a, &b);
        let comp_err = rel_frobenius_error(&exact, &matmul_f32(&a, &b));
        let naive_err = rel_frobenius_error(&exact, &matmul_f32_naive(&a, &b));
        if comp_err > naive_err {
            return Err(format!(
                "compensated {comp_err} worse than naive {naive_err} at k={k}"
            ));
        }
        if comp_err > Precision::F32.tier_tol() * 40.0 {
            return Err(format!("compensated err {comp_err} outside the tier budget at k={k}"));
        }
        Ok(())
    });
}

#[test]
fn prop_shard_cells_bit_identical_per_tier() {
    // The batcher's per-tier reproducibility contract, at the operator:
    // 1-4 output-dim shard cells must match the matching rows of the
    // unsharded tier apply bitwise, whatever the pool size implied.
    check("1-4 shard cells == unsharded apply per tier, bitwise", 16, |g| {
        let m = g.usize(4, 40);
        let n = g.usize(4, 60);
        let k = g.usize(1, 6);
        let shards = g.usize(1, 4.min(m));
        let seed = g.u64(0..=u64::MAX);
        let mut rng = g.rng();
        let x = Mat::gaussian(n, k, 1.0, &mut rng);
        let srht = SrhtSketcher::new(m, n, seed);
        let sparse = SparseSignSketcher::new(m, n, g.usize(1, 4.min(m)), seed);
        for tier in TIERS {
            let srht_full = srht.project_block_lowp(0..m, 0..n, &x, tier);
            let sparse_full = sparse.project_block_lowp(0..m, 0..n, &x, tier);
            for r in split_ranges(m, shards) {
                let cell = srht.project_block_lowp(r.clone(), 0..n, &x, tier);
                let scell = sparse.project_block_lowp(r.clone(), 0..n, &x, tier);
                for (bi, i) in r.enumerate() {
                    if cell.row(bi) != srht_full.row(i) {
                        return Err(format!(
                            "srht cell row {i} not bit-identical at tier={} m={m} n={n} \
                             shards={shards}",
                            tier.label()
                        ));
                    }
                    if scell.row(bi) != sparse_full.row(i) {
                        return Err(format!(
                            "sparse cell row {i} not bit-identical at tier={} m={m} n={n} \
                             shards={shards}",
                            tier.label()
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

fn host_coordinator() -> Coordinator {
    Coordinator::start(CoordinatorConfig {
        workers: 2,
        policy: Policy::ForceHost,
        batch: BatchConfig {
            max_wait: std::time::Duration::from_micros(50),
            noise: NoiseModel::ideal(),
            ..Default::default()
        },
        pool: PoolConfig { pjrt_replicas: 0, ..Default::default() },
        ..Default::default()
    })
    .expect("coordinator start")
}

#[test]
fn bf16_randsvd_spectra_within_documented_tier_tolerance_of_f64() {
    // Seeded end-to-end: the same RandSvd spec through the coordinator
    // at Bf16 and at f64 (operator identity is tier-independent, so the
    // draws match) — the spectra may differ only by tier arithmetic,
    // bounded by the documented Bf16 tolerance.
    let c = host_coordinator();
    for seed in [3u64, 11] {
        let target =
            matrix_with_spectrum(96, Spectrum::Exponential { decay: 0.85 }, seed);
        let spectrum_at = |precision: Precision| {
            let resp = c
                .run_spec(
                    JobSpec::RandSvd {
                        a: OperandRef::Inline(target.clone()),
                        rank: 12,
                        oversample: 8,
                        power_iters: 1,
                        publish_q: false,
                        tol: None,
                    },
                    SubmitOptions::default().with_precision(precision),
                )
                .expect("randsvd");
            assert_eq!(resp.precision, precision);
            let (_, s, _) = resp.payload.svd().expect("svd payload");
            s.to_vec()
        };
        let s64 = spectrum_at(Precision::F64);
        let s16 = spectrum_at(Precision::Bf16);
        assert_eq!(s64.len(), s16.len(), "tiers returned different ranks");
        let num: f64 = s64.iter().zip(&s16).map(|(x, y)| (x - y) * (x - y)).sum();
        let den: f64 = s64.iter().map(|x| x * x).sum();
        let rms = (num / den).sqrt();
        assert!(
            rms <= Precision::Bf16.tier_tol(),
            "seed {seed}: bf16 spectrum rel RMS {rms:.3e} exceeds tier tol {}",
            Precision::Bf16.tier_tol()
        );
    }
    c.shutdown();
}
