//! Property tests for the aperture shard planner + counter-based operator
//! (testkit, our proptest-lite): shard-and-recombine must reproduce the
//! digital projection for 1–4 shards along either axis.
//!
//! Exactness contract (see rust/src/coordinator/shard.rs):
//! - output-dim sharding is **bit-identical** to the unsharded projection
//!   (each output row is produced by exactly one cell over the full input
//!   range, in the same accumulation order);
//! - input-dim sharding is bit-identical to the shard-sum reference
//!   `Σᵢ Gᵢ Xᵢ` folded in plan order, and equal to the unsharded
//!   projection up to f64 summation association (<= 1e-12 relative);
//! - the composite operator never changes: blocks of one counter seed
//!   tile into exactly the full G.

use photonic_randnla::coordinator::shard::{recombine, ShardPlan};
use photonic_randnla::linalg::{matmul, rel_frobenius_error, Mat};
use photonic_randnla::parallel::split_ranges;
use photonic_randnla::randnla::backend::CounterSketcher;
use photonic_randnla::testkit::check;

/// A plan with exact shard counts along each axis (vs. for_aperture,
/// which derives counts from an aperture).
fn plan_with_counts(m: usize, n: usize, out_shards: usize, in_shards: usize) -> ShardPlan {
    ShardPlan {
        m,
        n,
        out_splits: split_ranges(m, out_shards),
        in_splits: split_ranges(n, in_shards),
    }
}

/// Execute a plan the way the coordinator's host arm does: one
/// counter-operator block + matmul per cell, recombined in plan order.
fn execute_plan(cs: &CounterSketcher, plan: &ShardPlan, x: &Mat) -> Mat {
    let partials: Vec<Mat> = plan
        .cells()
        .iter()
        .map(|c| {
            let g = cs.block(c.out.clone(), c.inp.clone());
            let xb = Mat::from_fn(c.inp.len(), x.cols, |i, j| x.at(c.inp.start + i, j));
            matmul(&g, &xb)
        })
        .collect();
    recombine(plan, x.cols, &partials)
}

#[test]
fn prop_output_dim_sharding_bit_identical() {
    check("1-4 output shards == unsharded digital projection, bitwise", 40, |g| {
        let m = g.usize(4, 40);
        let n = g.usize(4, 60);
        let k = g.usize(1, 6);
        let shards = g.usize(1, 4.min(m));
        let seed = g.u64(0..=u64::MAX);
        let cs = CounterSketcher::new(m, n, seed);
        let mut rng = g.rng();
        let x = Mat::gaussian(n, k, 1.0, &mut rng);
        let got = execute_plan(&cs, &plan_with_counts(m, n, shards, 1), &x);
        let want = matmul(&cs.matrix(), &x);
        if got != want {
            return Err(format!(
                "output-dim sharding not bit-identical at m={m} n={n} k={k} shards={shards}"
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_input_dim_sharding_exact_recombination() {
    check("1-4 input shards: Σᵢ GᵢXᵢ reference, ~unsharded", 40, |g| {
        let m = g.usize(4, 32);
        let n = g.usize(4, 64);
        let k = g.usize(1, 6);
        let shards = g.usize(1, 4.min(n));
        let seed = g.u64(0..=u64::MAX);
        let cs = CounterSketcher::new(m, n, seed);
        let mut rng = g.rng();
        let x = Mat::gaussian(n, k, 1.0, &mut rng);
        let plan = plan_with_counts(m, n, 1, shards);
        let got = execute_plan(&cs, &plan, &x);

        // Bit-identical to the shard-sum reference folded in plan order.
        let mut reference = Mat::zeros(m, k);
        for cell in plan.cells() {
            let gb = cs.block(cell.out.clone(), cell.inp.clone());
            let xb = Mat::from_fn(cell.inp.len(), k, |i, j| x.at(cell.inp.start + i, j));
            let part = matmul(&gb, &xb);
            for i in 0..m {
                for (dst, s) in reference.row_mut(i).iter_mut().zip(part.row(i)) {
                    *dst += s;
                }
            }
        }
        if got != reference {
            return Err(format!(
                "input-dim sharding != shard-sum reference at m={m} n={n} shards={shards}"
            ));
        }

        // And matches the unsharded projection up to fp association.
        let unsharded = matmul(&cs.matrix(), &x);
        let rel = rel_frobenius_error(&unsharded, &got);
        if rel > 1e-12 {
            return Err(format!("input-dim sharding drifted {rel} at m={m} n={n}"));
        }
        // With a single shard the fold is the same computation: bitwise.
        if shards == 1 && got != unsharded {
            return Err("single input shard must be bit-identical".to_string());
        }
        Ok(())
    });
}

#[test]
fn prop_grid_sharding_matches_unsharded() {
    check("out x in shard grids reproduce the unsharded projection", 30, |g| {
        let m = g.usize(6, 30);
        let n = g.usize(6, 48);
        let k = g.usize(1, 5);
        let so = g.usize(1, 3.min(m));
        let si = g.usize(1, 3.min(n));
        let seed = g.u64(0..=u64::MAX);
        let cs = CounterSketcher::new(m, n, seed);
        let mut rng = g.rng();
        let x = Mat::gaussian(n, k, 1.0, &mut rng);
        let got = execute_plan(&cs, &plan_with_counts(m, n, so, si), &x);
        let want = matmul(&cs.matrix(), &x);
        let rel = rel_frobenius_error(&want, &got);
        if rel > 1e-12 {
            return Err(format!("grid {so}x{si} drifted {rel} at m={m} n={n} k={k}"));
        }
        Ok(())
    });
}

#[test]
fn prop_plans_are_independent_of_evaluation_order_inputs() {
    // Determinism for a fixed plan: executing the same plan twice (fresh
    // blocks each time) is bit-identical — there is no hidden state.
    check("same plan executed twice is bit-identical", 20, |g| {
        let m = g.usize(4, 24);
        let n = g.usize(4, 40);
        let so = g.usize(1, 3.min(m));
        let si = g.usize(1, 3.min(n));
        let seed = g.u64(0..=u64::MAX);
        let mut rng = g.rng();
        let x = Mat::gaussian(n, g.usize(1, 4), 1.0, &mut rng);
        let plan = plan_with_counts(m, n, so, si);
        let a = execute_plan(&CounterSketcher::new(m, n, seed), &plan, &x);
        let b = execute_plan(&CounterSketcher::new(m, n, seed), &plan, &x);
        if a != b {
            return Err(format!("plan execution nondeterministic at m={m} n={n}"));
        }
        Ok(())
    });
}

#[test]
fn prop_aperture_plans_cover_and_respect_limits() {
    check("for_aperture covers both axes with cells within limits", 60, |g| {
        let m = g.usize(1, 200);
        let n = g.usize(1, 200);
        let max_m = g.usize(1, 64);
        let max_n = g.usize(1, 64);
        let plan = ShardPlan::for_aperture(m, n, max_m, max_n);
        let out_total: usize = plan.out_splits.iter().map(|r| r.len()).sum();
        let in_total: usize = plan.in_splits.iter().map(|r| r.len()).sum();
        if out_total != m || in_total != n {
            return Err(format!("coverage broken: {out_total}/{m}, {in_total}/{n}"));
        }
        for c in plan.cells() {
            if c.out.len() > max_m || c.inp.len() > max_n {
                return Err(format!(
                    "cell {}x{} exceeds aperture {max_m}x{max_n}",
                    c.out.len(),
                    c.inp.len()
                ));
            }
        }
        // Contiguity: consecutive splits tile without gaps.
        for w in plan.out_splits.windows(2) {
            if w[0].end != w[1].start {
                return Err("output splits not contiguous".to_string());
            }
        }
        for w in plan.in_splits.windows(2) {
            if w[0].end != w[1].start {
                return Err("input splits not contiguous".to_string());
            }
        }
        Ok(())
    });
}
