//! Integration: the network front door over loopback TCP.
//!
//! Covers the serving plane's multi-tenant wire contract end to end:
//! - four tenants drive one server concurrently and every remote result
//!   is bit-identical to the in-process `submit_spec` reference at
//!   every precision tier;
//! - a bad token is refused with a typed auth error before any session
//!   state exists; a wrong protocol version likewise;
//! - `Busy` backpressure and per-tenant `OverQuota` arrive as the same
//!   typed errors an embedded client sees, and one tenant at its quota
//!   cap never affects another;
//! - sessions are isolated: a foreign handle is indistinguishable from
//!   an unknown one, for frees and submissions alike;
//! - remote cancel-by-id kills a queued job before it runs;
//! - an unknown frame tag is skipped cleanly (typed status, connection
//!   survives);
//! - graceful shutdown drains in-flight jobs: every acked submission
//!   resolves exactly once, none lost, none double-reported;
//! - the streaming plane round-trips: begin/append/seal/submit/free.

use std::io::Write as _;
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use photonic_randnla::coordinator::wire::{read_frame, write_frame};
use photonic_randnla::coordinator::{
    BatchConfig, Coordinator, CoordinatorConfig, Frame, JobError, JobSpec, OperandRef, Policy,
    PoolConfig, Precision, QosClass, StatusCode, StoreError, StreamOpts, SubmitError,
    SubmitOptions, TenantRegistry, TraceEstimator, WIRE_VERSION,
};
use photonic_randnla::linalg::Mat;
use photonic_randnla::net::{ClientError, WireClient, WireServer};
use photonic_randnla::opu::NoiseModel;
use photonic_randnla::rng::Xoshiro256;
use photonic_randnla::testkit::ephemeral_loopback;

fn coordinator(queue_cap: usize, workers: usize) -> Coordinator {
    Coordinator::start(CoordinatorConfig {
        workers,
        policy: Policy::ForceHost,
        batch: BatchConfig {
            max_cols: 1,
            max_wait: Duration::from_micros(50),
            noise: NoiseModel::ideal(),
            ..Default::default()
        },
        pool: PoolConfig { pjrt_replicas: 0, ..Default::default() },
        queue_cap,
        ..Default::default()
    })
    .expect("coordinator start")
}

fn server(queue_cap: usize, workers: usize, tenants: TenantRegistry) -> WireServer {
    WireServer::start(coordinator(queue_cap, workers), &ephemeral_loopback(), tenants)
        .expect("server start")
}

fn inline_projection() -> JobSpec {
    JobSpec::Projection { data: OperandRef::Inline(Mat::zeros(32, 2)), m: 8 }
}

#[test]
fn four_tenants_concurrent_and_bit_identical_across_tiers() {
    let tenants = TenantRegistry::new()
        .add("t0", "tok0", usize::MAX, QosClass::Interactive)
        .add("t1", "tok1", usize::MAX, QosClass::Interactive)
        .add("t2", "tok2", usize::MAX, QosClass::Batch)
        .add("t3", "tok3", usize::MAX, QosClass::Batch);
    let srv = server(256, 4, tenants);
    let addr = srv.addr();

    // In-process reference on an identically configured engine: the
    // signature-seeded operator makes results engine-independent.
    let tiers = [Precision::F64, Precision::F32, Precision::Bf16];
    let mut rng = Xoshiro256::new(9);
    let x = Mat::gaussian(192, 8, 1.0, &mut rng);
    let local = coordinator(256, 4);
    let lid = local.upload(x.clone()).unwrap();
    let expected: Vec<Mat> = tiers
        .iter()
        .map(|&p| {
            local
                .run_spec(
                    JobSpec::Projection { data: OperandRef::Handle(lid), m: 16 },
                    SubmitOptions::default().with_precision(p),
                )
                .unwrap()
                .payload
                .matrix()
                .unwrap()
                .clone()
        })
        .collect();
    local.shutdown();

    let threads: Vec<_> = (0..4)
        .map(|i| {
            let x = x.clone();
            let expected = expected.clone();
            std::thread::spawn(move || {
                let client = WireClient::connect(addr, &format!("tok{i}")).unwrap();
                assert_eq!(client.tenant(), format!("t{i}"));
                let id = client.upload(&x).unwrap();
                for (j, &p) in tiers.iter().enumerate() {
                    let r = client
                        .run(
                            &JobSpec::Projection { data: OperandRef::Handle(id), m: 16 },
                            SubmitOptions::default().with_precision(p),
                        )
                        .unwrap();
                    assert_eq!(r.precision, p);
                    assert_eq!(
                        r.payload.matrix().unwrap(),
                        &expected[j],
                        "tier {p:?} diverged over the wire"
                    );
                }
                assert!(client.free_operand(id).is_ok());
            })
        })
        .collect();
    for t in threads {
        t.join().unwrap();
    }

    // The journal carries per-tenant lifecycle and the report carries
    // per-tenant counters for every principal that connected.
    let report = srv.coordinator().metrics.report();
    for name in ["t0", "t1", "t2", "t3"] {
        assert!(report.contains(&format!("tenant[{name}]")), "missing tenant line:\n{report}");
    }
    srv.shutdown();
}

#[test]
fn bad_token_is_refused_with_typed_auth_error() {
    let srv = server(64, 1, TenantRegistry::new().add("a", "good", usize::MAX, QosClass::Batch));
    match WireClient::connect(srv.addr(), "wrong") {
        Err(ClientError::Auth(detail)) => assert!(detail.contains("unknown token")),
        other => panic!("expected auth refusal, got {other:?}", other = other.err()),
    }
    // The good token still works afterwards.
    let client = WireClient::connect(srv.addr(), "good").unwrap();
    assert_eq!(client.tenant(), "a");
    drop(client);
    srv.shutdown();
}

#[test]
fn busy_backpressure_is_typed_over_the_wire() {
    let srv = server(1, 1, TenantRegistry::new().add("a", "tok", usize::MAX, QosClass::Batch));
    let coord = Arc::clone(srv.coordinator());
    let client = WireClient::connect(srv.addr(), "tok").unwrap();

    coord.pause();
    let first = client.submit(&inline_projection(), SubmitOptions::default()).unwrap();
    match client.submit(&inline_projection(), SubmitOptions::default()) {
        Err(ClientError::Submit(SubmitError::Busy { depth, cap })) => {
            assert_eq!((depth, cap), (1, 1));
        }
        other => panic!("expected typed Busy, got {other:?}", other = other.err()),
    }
    coord.resume();
    assert!(first.wait().is_ok());
    drop(client);
    srv.shutdown();
}

#[test]
fn per_tenant_quota_is_isolated() {
    // Alice is capped at 1 MiB; Bob and the global store are unbounded.
    let tenants = TenantRegistry::new()
        .add("alice", "a-tok", 1 << 20, QosClass::Interactive)
        .add("bob", "b-tok", usize::MAX, QosClass::Interactive);
    let srv = server(64, 2, tenants);
    let alice = WireClient::connect(srv.addr(), "a-tok").unwrap();
    let bob = WireClient::connect(srv.addr(), "b-tok").unwrap();
    assert_eq!(alice.quota(), 1 << 20);

    // 256 x 256 f64 = 512 KiB: two fit exactly, the third crosses.
    let half = Mat::zeros(256, 256);
    let id1 = alice.upload(&half).unwrap();
    let _id2 = alice.upload(&half).unwrap();
    match alice.upload(&half) {
        Err(ClientError::Store(StoreError::OverQuota { needed, used, quota })) => {
            assert_eq!((needed, used, quota), (512 << 10, 1 << 20, 1 << 20));
        }
        other => panic!("expected typed OverQuota, got {other:?}", other = other.err()),
    }

    // Bob is unaffected by Alice sitting at her cap, and Alice's
    // existing handles still serve.
    let bid = bob.upload(&half).unwrap();
    assert!(bob
        .run(
            &JobSpec::Projection { data: OperandRef::Handle(bid), m: 4 },
            SubmitOptions::default()
        )
        .is_ok());
    assert!(alice
        .run(
            &JobSpec::Projection { data: OperandRef::Handle(id1), m: 4 },
            SubmitOptions::default()
        )
        .is_ok());

    // Freeing a copy returns its bytes: the next upload is admitted.
    assert!(alice.free_operand(id1).is_ok());
    assert!(alice.upload(&half).is_ok());

    let report = srv.coordinator().metrics.report();
    assert!(report.contains("tenant[alice]"), "missing alice counters:\n{report}");
    assert!(report.contains("quota=1"), "quota rejection not counted:\n{report}");
    drop((alice, bob));
    srv.shutdown();
}

#[test]
fn sessions_cannot_touch_foreign_ids() {
    let tenants = TenantRegistry::new()
        .add("alice", "a-tok", usize::MAX, QosClass::Interactive)
        .add("bob", "b-tok", usize::MAX, QosClass::Interactive);
    let srv = server(64, 1, tenants);
    let alice = WireClient::connect(srv.addr(), "a-tok").unwrap();
    let bob = WireClient::connect(srv.addr(), "b-tok").unwrap();

    let id = alice.upload(&Mat::zeros(16, 4)).unwrap();
    // Bob cannot free or reference Alice's handle: both refusals are
    // the same typed error a stale handle raises.
    assert_eq!(bob.free_operand(id), Err(ClientError::Submit(SubmitError::UnknownOperand(id))));
    match bob.submit(
        &JobSpec::Projection { data: OperandRef::Handle(id), m: 4 },
        SubmitOptions::default(),
    ) {
        Err(ClientError::Submit(SubmitError::UnknownOperand(got))) => assert_eq!(got, id),
        other => panic!("expected UnknownOperand, got {other:?}", other = other.err()),
    }
    // Alice still owns it.
    assert!(alice
        .run(
            &JobSpec::Projection { data: OperandRef::Handle(id), m: 4 },
            SubmitOptions::default()
        )
        .is_ok());
    drop((alice, bob));
    srv.shutdown();
}

#[test]
fn remote_cancel_by_id_kills_a_queued_job() {
    let srv = server(64, 1, TenantRegistry::new().add("a", "tok", usize::MAX, QosClass::Batch));
    let coord = Arc::clone(srv.coordinator());
    let client = WireClient::connect(srv.addr(), "tok").unwrap();

    coord.pause();
    let ticket = client.submit(&inline_projection(), SubmitOptions::default()).unwrap();
    assert_eq!(client.cancel(ticket.id()), Ok(true), "queued job must be cancellable");
    // Cancelling an unknown/finished id is a clean false, not an error.
    assert_eq!(client.cancel(ticket.id() + 1000), Ok(false));
    coord.resume();
    assert_eq!(ticket.wait().unwrap_err(), JobError::Cancelled);
    drop(client);
    srv.shutdown();
}

#[test]
fn unknown_tag_and_bad_version_on_a_raw_socket() {
    let srv = server(64, 1, TenantRegistry::new().add("a", "tok", usize::MAX, QosClass::Batch));

    // Wrong protocol version: typed auth refusal, then the server hangs up.
    let mut s = TcpStream::connect(srv.addr()).unwrap();
    write_frame(&mut s, 1, &Frame::Hello { version: WIRE_VERSION + 1, token: "tok".into() })
        .unwrap();
    let (req, frame) = read_frame(&mut s).unwrap();
    assert_eq!(req, 1);
    match frame {
        Frame::Status(st) => assert_eq!(st.code, StatusCode::AuthFailed),
        other => panic!("expected Status, got tag {}", other.tag()),
    }

    // Fresh connection, real handshake, then an unassigned tag with a
    // payload: the server must consume it, answer typed, and keep the
    // session alive.
    let mut s = TcpStream::connect(srv.addr()).unwrap();
    write_frame(&mut s, 1, &Frame::Hello { version: WIRE_VERSION, token: "tok".into() }).unwrap();
    let (_, hello) = read_frame(&mut s).unwrap();
    assert!(matches!(hello, Frame::HelloOk { .. }), "handshake failed: tag {}", hello.tag());

    let payload = vec![0xAB; 17];
    let mut bytes = Vec::new();
    bytes.extend_from_slice(&((8 + 2 + payload.len()) as u32).to_le_bytes());
    bytes.extend_from_slice(&7u64.to_le_bytes());
    bytes.extend_from_slice(&20u16.to_le_bytes()); // unassigned tag
    bytes.extend_from_slice(&payload);
    s.write_all(&bytes).unwrap();
    let (req, frame) = read_frame(&mut s).unwrap();
    assert_eq!(req, 7);
    match frame {
        Frame::Status(st) => {
            assert_eq!(st.code, StatusCode::UnknownTag);
            assert_eq!(st.a, 20, "status must name the offending tag");
        }
        other => panic!("expected Status, got tag {}", other.tag()),
    }
    // The connection survived the skip: a normal request still works.
    write_frame(&mut s, 8, &Frame::Report).unwrap();
    let (req, frame) = read_frame(&mut s).unwrap();
    assert_eq!(req, 8);
    assert!(matches!(frame, Frame::ReportText { .. }), "got tag {}", frame.tag());
    drop(s);
    srv.shutdown();
}

#[test]
fn graceful_shutdown_drains_every_acked_job_exactly_once() {
    let srv = server(1024, 2, TenantRegistry::new().add("a", "tok", usize::MAX, QosClass::Batch));
    let coord = Arc::clone(srv.coordinator());
    let client = WireClient::connect(srv.addr(), "tok").unwrap();

    // Pause the workers so every job is still in flight when shutdown
    // begins: the drain, not luck, must deliver the results.
    coord.pause();
    let tickets: Vec<_> = (0..16)
        .map(|_| client.submit(&inline_projection(), SubmitOptions::default()).unwrap())
        .collect();
    let shutdown = std::thread::spawn(move || srv.shutdown());
    std::thread::sleep(Duration::from_millis(100));
    coord.resume();

    // Every acked submission resolves exactly once (wait consumes the
    // ticket) and none may be lost to the shutdown race.
    for (i, t) in tickets.into_iter().enumerate() {
        match t.wait() {
            Ok(r) => assert_eq!(r.kind, "projection"),
            Err(e) => panic!("job {i} lost during graceful shutdown: {e:?}"),
        }
    }
    shutdown.join().unwrap();
    // The engine refused nothing silently: submits after shutdown fail
    // fast with a transport/closed error instead of hanging.
    assert!(client.submit(&inline_projection(), SubmitOptions::default()).is_err());
}

#[test]
fn stream_lifecycle_round_trips_over_the_wire() {
    let srv = server(64, 2, TenantRegistry::new().add("a", "tok", usize::MAX, QosClass::Batch));
    let client = WireClient::connect(srv.addr(), "tok").unwrap();

    let mut rng = Xoshiro256::new(4);
    let a = Mat::gaussian(64, 64, 1.0, &mut rng);
    let sid = client.begin_stream(64, 64, StreamOpts::default()).unwrap();
    // Two chunks exercise the append path's re-framing.
    let top = Mat { rows: 32, cols: 64, data: a.data[..32 * 64].to_vec() };
    let bot = Mat { rows: 32, cols: 64, data: a.data[32 * 64..].to_vec() };
    client.append_stream(sid, &top).unwrap();
    client.append_stream(sid, &bot).unwrap();
    client.seal_stream(sid).unwrap();

    let r = client
        .run(
            &JobSpec::Trace {
                a: OperandRef::Stream(sid),
                m: 64,
                estimator: TraceEstimator::Hutchinson,
            },
            SubmitOptions::default(),
        )
        .unwrap();
    assert!(r.payload.scalar().is_some(), "stream trace must yield a scalar");
    assert_eq!(client.free_stream(sid), Ok(true));

    // A foreign stream id is a typed refusal, like a stale one.
    match client.submit(
        &JobSpec::Trace {
            a: OperandRef::Stream(sid),
            m: 64,
            estimator: TraceEstimator::Hutchinson,
        },
        SubmitOptions::default(),
    ) {
        Err(ClientError::Submit(SubmitError::UnknownStream(got))) => assert_eq!(got, sid),
        other => panic!("expected UnknownStream, got {other:?}", other = other.err()),
    }
    drop(client);
    srv.shutdown();
}
