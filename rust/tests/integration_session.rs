//! Integration: the handle-based session API and its QoS semantics.
//!
//! Covers the serving plane's front-door contract:
//! - cancelled jobs never run; expired deadlines fail fast without
//!   touching a device; Interactive overtakes queued Batch work; a full
//!   admission queue yields typed `Busy` backpressure;
//! - handle and inline submissions of one operand are bit-identical;
//! - k jobs against one uploaded operand perform exactly one deep copy
//!   of it end-to-end (store accounting + `Arc::strong_count`);
//! - a plan's shared symmetric sketch feeds Trace and Triangles without
//!   recomputing the projection.
//!
//! All tests run on the host arm (no artifacts needed) and use
//! `pause`/`resume` to make queue-ordering assertions deterministic.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use photonic_randnla::coordinator::{
    BatchConfig, Coordinator, CoordinatorConfig, Job, JobError, JobSpec, OperandRef, Plan,
    Policy, PoolConfig, SubmitError, SubmitOptions,
};
use photonic_randnla::linalg::Mat;
use photonic_randnla::opu::NoiseModel;
use photonic_randnla::rng::Xoshiro256;
use photonic_randnla::workload::psd_matrix;

fn host_coordinator(workers: usize, queue_cap: usize) -> Coordinator {
    Coordinator::start(CoordinatorConfig {
        workers,
        policy: Policy::ForceHost,
        batch: BatchConfig {
            // Flush every request as its own single-request batch: the
            // zero-copy fast path, and deterministic batch counting.
            max_cols: 1,
            max_wait: Duration::from_micros(50),
            noise: NoiseModel::ideal(),
            ..Default::default()
        },
        pool: PoolConfig { pjrt_replicas: 0, ..Default::default() },
        queue_cap,
        ..Default::default()
    })
    .expect("coordinator start")
}

/// Spin until `f` holds (bounded); returns its final value.
fn eventually(mut f: impl FnMut() -> bool) -> bool {
    for _ in 0..400 {
        if f() {
            return true;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    f()
}

#[test]
fn cancelled_job_never_runs() {
    let c = host_coordinator(1, 64);
    c.pause();
    let t = c
        .submit_spec(
            JobSpec::Projection { data: OperandRef::Inline(Mat::zeros(32, 2)), m: 8 },
            SubmitOptions::default(),
        )
        .unwrap();
    assert!(t.cancel(), "queued job must be cancellable");
    c.resume();
    assert_eq!(t.wait().unwrap_err(), JobError::Cancelled);
    assert_eq!(c.metrics.cancelled.load(Ordering::Relaxed), 1);
    assert_eq!(c.metrics.completed.load(Ordering::Relaxed), 0);
    // The projection plane was never touched.
    assert_eq!(c.metrics.batches.load(Ordering::Relaxed), 0);
    c.shutdown();
}

#[test]
fn expired_deadline_fails_fast_without_touching_a_device() {
    let c = host_coordinator(1, 64);
    c.pause();
    let t = c
        .submit_spec(
            JobSpec::Projection { data: OperandRef::Inline(Mat::zeros(32, 2)), m: 8 },
            SubmitOptions::default().with_deadline(Duration::from_millis(1)),
        )
        .unwrap();
    std::thread::sleep(Duration::from_millis(10));
    c.resume();
    match t.wait().unwrap_err() {
        JobError::DeadlineExceeded { deadline, waited } => {
            assert_eq!(deadline, Duration::from_millis(1));
            assert!(waited >= Duration::from_millis(10), "waited {waited:?}");
        }
        other => panic!("expected DeadlineExceeded, got {other:?}"),
    }
    assert_eq!(c.metrics.deadline_expired.load(Ordering::Relaxed), 1);
    assert_eq!(c.metrics.batches.load(Ordering::Relaxed), 0, "expired job touched a device");

    // A generous deadline sails through.
    let ok = c
        .run_spec(
            JobSpec::Projection { data: OperandRef::Inline(Mat::zeros(32, 2)), m: 8 },
            SubmitOptions::default().with_deadline(Duration::from_secs(30)),
        )
        .unwrap();
    assert_eq!(ok.kind, "projection");
    c.shutdown();
}

#[test]
fn interactive_overtakes_queued_batch() {
    let c = host_coordinator(1, 64);
    let mut rng = Xoshiro256::new(3);
    let x = Mat::gaussian(32, 2, 1.0, &mut rng);
    c.pause();
    // Batch submitted FIRST, interactive second; with one worker the
    // completion sequence proves who ran first.
    let tb = c
        .submit_spec(
            JobSpec::Projection { data: OperandRef::Inline(x.clone()), m: 8 },
            SubmitOptions::default(),
        )
        .unwrap();
    let ti = c
        .submit_spec(
            JobSpec::Projection { data: OperandRef::Inline(x), m: 8 },
            SubmitOptions::interactive(),
        )
        .unwrap();
    let (qi, qb) = c.queue_depths();
    assert_eq!((qi, qb), (1, 1));
    c.resume();
    let rb = tb.wait().unwrap();
    let ri = ti.wait().unwrap();
    assert!(
        ri.seq < rb.seq,
        "interactive (seq {}) must complete before batch (seq {})",
        ri.seq,
        rb.seq
    );
    c.shutdown();
}

#[test]
fn full_queue_yields_busy_backpressure() {
    let c = host_coordinator(1, 2);
    c.pause();
    let spec = || JobSpec::Projection { data: OperandRef::Inline(Mat::zeros(16, 1)), m: 4 };
    let t1 = c.submit_spec(spec(), SubmitOptions::default()).unwrap();
    let t2 = c.submit_spec(spec(), SubmitOptions::default()).unwrap();
    let err = c.submit_spec(spec(), SubmitOptions::default()).unwrap_err();
    assert_eq!(err, SubmitError::Busy { depth: 2, cap: 2 });
    assert_eq!(c.metrics.rejected_busy.load(Ordering::Relaxed), 1);
    // The legacy infallible submit absorbs the backpressure instead:
    // it waits for queue space (old unbounded-channel semantics at
    // bounded memory) and the job still completes.
    std::thread::scope(|s| {
        let shim = s.spawn(|| c.submit(Job::Projection { data: Mat::zeros(16, 1), m: 4 }).wait());
        std::thread::sleep(Duration::from_millis(20));
        c.resume();
        let r = shim.join().expect("legacy submit thread");
        assert!(r.is_ok(), "legacy submit must wait out backpressure: {r:?}");
    });
    t1.wait().unwrap();
    t2.wait().unwrap();
    c.shutdown();
}

#[test]
fn handle_and_inline_submissions_are_bit_identical() {
    let c = host_coordinator(2, 64);
    let mut rng = Xoshiro256::new(5);
    let x = Mat::gaussian(48, 3, 1.0, &mut rng);
    let id = c.upload(x.clone()).unwrap();
    let via_handle = c
        .run_spec(
            JobSpec::Projection { data: OperandRef::Handle(id), m: 12 },
            SubmitOptions::default(),
        )
        .unwrap();
    let via_inline = c
        .run_spec(
            JobSpec::Projection { data: OperandRef::Inline(x), m: 12 },
            SubmitOptions::default(),
        )
        .unwrap();
    assert_eq!(
        via_handle.payload.matrix().unwrap(),
        via_inline.payload.matrix().unwrap(),
        "same operand, same signature operator — results must match bitwise"
    );
    c.shutdown();
}

#[test]
fn k_jobs_against_one_upload_cost_exactly_one_deep_copy() {
    let c = host_coordinator(2, 64);
    let mut rng = Xoshiro256::new(7);
    let (n, cols, k_jobs) = (256usize, 8usize, 8usize);
    let x = Mat::gaussian(n, cols, 1.0, &mut rng);
    let operand_bytes = n * cols * std::mem::size_of::<f64>();

    // The upload is the one deep transfer (a move into the store).
    let id = c.upload(x).unwrap();
    let resident = c.store().get(id).unwrap();
    assert_eq!(Arc::strong_count(&resident), 2, "store + this test");

    for _ in 0..k_jobs {
        let r = c
            .run_spec(
                JobSpec::Projection { data: OperandRef::Handle(id), m: 16 },
                SubmitOptions::default(),
            )
            .unwrap();
        assert_eq!(r.payload.matrix().unwrap().rows, 16);
    }

    // Store accounting: k jobs later, exactly one operand's bytes are
    // resident and the serving path copied zero operand bytes.
    assert_eq!(c.store().len(), 1);
    assert_eq!(c.store().bytes(), operand_bytes);
    assert_eq!(
        c.metrics.operand_bytes_copied.load(Ordering::Relaxed),
        0,
        "handle path must not deep-copy the operand"
    );
    // Transient Arc clones (queue, batcher, shard executor) all drain:
    // back to store + test.
    assert!(
        eventually(|| Arc::strong_count(&resident) == 2),
        "leaked operand refs: strong_count = {}",
        Arc::strong_count(&resident)
    );
    c.free_operand(id);
    assert_eq!(c.store().bytes(), 0);
    c.shutdown();
}

#[test]
fn plan_shared_sketch_feeds_trace_and_triangles_without_reprojection() {
    let c = host_coordinator(2, 64);
    let a = psd_matrix(32, 16, 9);
    let id = c.upload(a.clone()).unwrap();

    let mut plan = Plan::new();
    let sketch = plan.stage(JobSpec::SymmetricSketch { a: OperandRef::Handle(id), m: 8 });
    let t_stage = plan.stage(JobSpec::TraceOf { b: OperandRef::Stage(sketch) });
    let tri_stage = plan.stage(JobSpec::TrianglesOf { b: OperandRef::Stage(sketch) });

    let result = c.run_plan(&plan, SubmitOptions::default()).unwrap();
    // The symmetric sketch takes exactly two projection passes; the
    // downstream stages reuse the stage-1 handle and project nothing.
    assert_eq!(
        c.metrics.batches.load(Ordering::Relaxed),
        2,
        "plan recomputed the projection"
    );
    let b_handle = result.handle(sketch).expect("sketch stage publishes a handle");
    let b = c.store().get(b_handle).unwrap();
    assert_eq!((b.rows, b.cols), (8, 8));
    assert!(result.handle(t_stage).is_none(), "scalar stages publish no handle");

    // The plan's estimates equal the monolithic jobs' bit for bit (same
    // signature operator, same arithmetic)...
    let trace_plan = result.responses[t_stage].payload.scalar().unwrap();
    let tri_plan = result.responses[tri_stage].payload.scalar().unwrap();
    let trace_direct = c
        .run(Job::Trace { a: a.clone(), m: 8 })
        .unwrap()
        .payload
        .scalar()
        .unwrap();
    let tri_direct = c
        .run(Job::Triangles { adjacency: a, m: 8 })
        .unwrap()
        .payload
        .scalar()
        .unwrap();
    assert_eq!(trace_plan, trace_direct);
    assert_eq!(tri_plan, tri_direct);
    // ...but the monolithic pair costs two projection passes EACH.
    assert_eq!(c.metrics.batches.load(Ordering::Relaxed), 6);

    result.free_stage_handles(c.store());
    c.free_operand(id);
    assert_eq!(c.store().bytes(), 0, "plan left operands resident");
    c.shutdown();
}

#[test]
fn failing_plan_stage_frees_partial_handles() {
    let c = host_coordinator(2, 64);
    let a = psd_matrix(24, 12, 13);
    let id = c.upload(a).unwrap();
    let before = c.store().bytes();
    let mut plan = Plan::new();
    plan.stage(JobSpec::SymmetricSketch { a: OperandRef::Handle(id), m: 6 });
    // Undersized lstsq sketch: this stage fails at execution, after the
    // sketch stage already parked its output in the store.
    plan.stage(JobSpec::Lstsq { a: OperandRef::Handle(id), b: vec![0.0; 24], m: 2, refine: None });
    let err = c.run_plan(&plan, SubmitOptions::default()).unwrap_err();
    assert!(matches!(err, JobError::Failed(_)), "{err:?}");
    assert_eq!(c.store().bytes(), before, "failed plan leaked stage handles");
    c.free_operand(id);
    c.shutdown();
}

#[test]
fn freed_handle_is_typed_error_but_inflight_jobs_survive_free() {
    let c = host_coordinator(1, 64);
    let mut rng = Xoshiro256::new(11);

    // Stale handle: typed refusal at submit.
    let dead = c.upload(Mat::gaussian(16, 1, 1.0, &mut rng)).unwrap();
    c.free_operand(dead);
    let err = c
        .submit_spec(
            JobSpec::Projection { data: OperandRef::Handle(dead), m: 4 },
            SubmitOptions::default(),
        )
        .unwrap_err();
    assert_eq!(err, SubmitError::UnknownOperand(dead));

    // Free *after* submit: the resolved job holds the Arc and completes.
    let live = c.upload(Mat::gaussian(16, 1, 1.0, &mut rng)).unwrap();
    c.pause();
    let t = c
        .submit_spec(
            JobSpec::Projection { data: OperandRef::Handle(live), m: 4 },
            SubmitOptions::default(),
        )
        .unwrap();
    assert!(c.free_operand(live));
    c.resume();
    let r = t.wait().expect("free-after-submit must not strand the job");
    assert_eq!(r.payload.matrix().unwrap().rows, 4);
    c.shutdown();
}
