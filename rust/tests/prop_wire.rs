//! Property tests: the framed wire codec never lies and never panics.
//!
//! Seeded random frames (every variant, hostile floats, non-ASCII
//! strings) must round-trip bit-exactly through encode/decode; every
//! malformed input — truncated prefixes, corrupt bytes, oversized
//! length headers, trailing garbage — must come back as a typed
//! [`WireError`], never a panic; and an unassigned tag must be skipped
//! cleanly so the stream keeps decoding behind it.
//!
//! Replay a failing case with `PHOTON_PROPTEST_SEED=<seed>`.

use std::io::Cursor;

use photonic_randnla::coordinator::wire::{
    decode_body, encode_frame, read_frame, Frame, StatusCode, WireError, WireLsqr, WireMat,
    WireOptions, WirePayload, WireRef, WireResponse, WireSpec, WireStatus, MAX_FRAME_BYTES,
};
use photonic_randnla::testkit::{check, Gen};

// ---------------------------------------------------------------------
// Generators
// ---------------------------------------------------------------------

/// f64 bit patterns including the hostile corners a lossy codec would
/// flatten: NaN payloads, infinities, signed zero, subnormals.
fn bits(g: &mut Gen) -> u64 {
    match g.usize(0, 5) {
        0 => f64::NAN.to_bits() | 0xDEAD,
        1 => f64::INFINITY.to_bits(),
        2 => f64::NEG_INFINITY.to_bits(),
        3 => (-0.0f64).to_bits(),
        4 => 0x0000_0000_0000_0001, // smallest subnormal
        _ => g.u64(0..=u64::MAX),
    }
}

fn gmat(g: &mut Gen) -> WireMat {
    let rows = g.u64(0..=4) as u32;
    let cols = g.u64(0..=4) as u32;
    let data = (0..rows as usize * cols as usize).map(|_| bits(g)).collect();
    WireMat { rows, cols, data }
}

fn gstr(g: &mut Gen) -> String {
    const ALPHABET: &[char] = &['a', 'Z', '0', '-', '_', ' ', 'µ', '✓'];
    let len = g.usize(0, 12);
    (0..len).map(|_| *g.pick(ALPHABET)).collect()
}

fn gref(g: &mut Gen) -> WireRef {
    match g.usize(0, 3) {
        0 => WireRef::Handle(g.u64(0..=u64::MAX)),
        1 => WireRef::Inline(gmat(g)),
        2 => WireRef::Stage(g.u64(0..=1 << 20)),
        _ => WireRef::Stream(g.u64(0..=u64::MAX)),
    }
}

fn gspec(g: &mut Gen) -> WireSpec {
    match g.usize(0, 9) {
        0 => WireSpec::Projection { data: gref(g), m: g.u64(1..=1 << 16) },
        1 => WireSpec::ApproxMatmul { a: gref(g), b: gref(g), m: g.u64(1..=1 << 16) },
        2 => WireSpec::Trace { a: gref(g), m: g.u64(1..=1 << 16), estimator: g.u64(0..=1) as u8 },
        3 => WireSpec::Triangles { adjacency: gref(g), m: g.u64(1..=1 << 16) },
        4 => WireSpec::SymmetricSketch { a: gref(g), m: g.u64(1..=1 << 16) },
        5 => WireSpec::TraceOf { b: gref(g) },
        6 => WireSpec::TrianglesOf { b: gref(g) },
        7 => WireSpec::RandSvd {
            a: gref(g),
            rank: g.u64(1..=256),
            oversample: g.u64(0..=32),
            power_iters: g.u64(0..=4),
            publish_q: g.bool(),
            tol: g.bool().then(|| bits(g)),
        },
        8 => WireSpec::Lstsq {
            a: gref(g),
            b: (0..g.usize(0, 8)).map(|_| bits(g)).collect(),
            m: g.u64(1..=1 << 16),
            refine: g
                .bool()
                .then(|| WireLsqr { tol: bits(g), max_iters: g.u64(1..=1 << 12) }),
        },
        _ => WireSpec::Nystrom { a: gref(g), m: g.u64(1..=1 << 16), rcond: bits(g) },
    }
}

fn gopts(g: &mut Gen) -> WireOptions {
    WireOptions {
        priority: g.u64(0..=1) as u8,
        deadline_us: g.bool().then(|| g.u64(0..=1 << 40)),
        precision: g.u64(0..=2) as u8,
        bypass_cache: g.bool(),
    }
}

fn gstatus(g: &mut Gen) -> WireStatus {
    WireStatus {
        code: StatusCode::from_code(g.usize(0, 19) as u16).expect("all 20 codes assigned"),
        detail: gstr(g),
        a: g.u64(0..=u64::MAX),
        b: g.u64(0..=u64::MAX),
        c: g.u64(0..=u64::MAX),
    }
}

fn gpayload(g: &mut Gen) -> WirePayload {
    match g.usize(0, 3) {
        0 => WirePayload::Matrix(gmat(g)),
        1 => WirePayload::Scalar(bits(g)),
        2 => WirePayload::Vector((0..g.usize(0, 8)).map(|_| bits(g)).collect()),
        _ => WirePayload::Svd {
            u: gmat(g),
            s: (0..g.usize(0, 4)).map(|_| bits(g)).collect(),
            vt: gmat(g),
        },
    }
}

fn gresponse(g: &mut Gen) -> WireResponse {
    WireResponse {
        id: g.u64(0..=u64::MAX),
        kind: gstr(g),
        payload: gpayload(g),
        device: g.u64(0..=2) as u8,
        precision: g.u64(0..=2) as u8,
        latency_us: g.u64(0..=u64::MAX),
        batched_cols: g.u64(0..=1 << 20),
        aux: (0..g.usize(0, 3)).map(|_| (gstr(g), g.u64(0..=u64::MAX))).collect(),
        seq: g.u64(0..=u64::MAX),
    }
}

/// A tag the protocol has not assigned (client/worker 1–16, server/
/// coordinator 32–48).
fn unassigned_tag(g: &mut Gen) -> u16 {
    loop {
        let t = g.u64(0..=u16::MAX as u64) as u16;
        if !(1..=16).contains(&t) && !(32..=48).contains(&t) {
            return t;
        }
    }
}

/// Every Frame variant, weighted uniformly.
fn gframe(g: &mut Gen) -> Frame {
    match g.usize(0, 33) {
        0 => Frame::Hello { version: g.u64(0..=u16::MAX as u64) as u16, token: gstr(g) },
        1 => Frame::Upload { mat: gmat(g) },
        2 => Frame::FreeOperand { id: g.u64(0..=u64::MAX) },
        3 => Frame::BeginStream {
            rows: g.u64(0..=1 << 24),
            cols: g.u64(0..=1 << 24),
            chunk_rows: g.u64(0..=1 << 16),
            sketch_m: g.u64(0..=1 << 16),
            fd_rank: g.u64(0..=1 << 16),
            range_cap: g.u64(0..=1 << 16),
        },
        4 => Frame::AppendStream { id: g.u64(0..=u64::MAX), rows: gmat(g) },
        5 => Frame::SealStream { id: g.u64(0..=u64::MAX) },
        6 => Frame::FreeStream { id: g.u64(0..=u64::MAX) },
        7 => Frame::Submit { spec: gspec(g), opts: gopts(g) },
        8 => Frame::Cancel { job: g.u64(0..=u64::MAX) },
        9 => Frame::Report,
        10 => Frame::Goodbye,
        11 => {
            Frame::HelloOk { tenant: gstr(g), qos: g.u64(0..=1) as u8, quota: g.u64(0..=u64::MAX) }
        }
        12 => Frame::Status(gstatus(g)),
        13 => Frame::OperandOk { id: g.u64(0..=u64::MAX), bytes: g.u64(0..=u64::MAX) },
        14 => Frame::Freed { existed: g.bool() },
        15 => Frame::StreamOk { id: g.u64(0..=u64::MAX) },
        16 => Frame::Ack,
        17 => Frame::Submitted { job: g.u64(0..=u64::MAX) },
        18 => Frame::JobDone(gresponse(g)),
        19 => Frame::CancelOk { cancelled: g.bool() },
        20 => Frame::ReportText { text: gstr(g) },
        21 => Frame::ShuttingDown,
        // The scale-out plane's worker/coordinator frames.
        22 => Frame::WorkerHello { version: g.u64(0..=u16::MAX as u64) as u16, token: gstr(g) },
        23 => Frame::SlotSummary {
            stream: g.u64(0..=u64::MAX),
            slot: g.u64(0..=1 << 8),
            r0: g.u64(0..=1 << 24),
            r1: g.u64(0..=1 << 24),
            chunks: g.u64(0..=1 << 16),
            fro2: bits(g),
            arm: g.u64(0..=3) as u8,
            y_arm: g.u64(0..=3) as u8,
            sa: gmat(g),
            yt: gmat(g),
            ingest_us: g.u64(0..=u64::MAX),
        },
        24 => Frame::PartitionSealed {
            stream: g.u64(0..=u64::MAX),
            epoch: g.u64(0..=1 << 16),
            fd_bound: bits(g),
            fd: gmat(g),
            seal_us: g.u64(0..=u64::MAX),
        },
        25 => Frame::PartitionFreed { stream: g.u64(0..=u64::MAX) },
        26 => Frame::WorkerOk {
            worker: g.u64(0..=u64::MAX),
            seed: g.u64(0..=u64::MAX),
            chunk_rows: g.u64(0..=1 << 16),
        },
        27 => Frame::AssignPartition {
            stream: g.u64(0..=u64::MAX),
            epoch: g.u64(0..=1 << 16),
            slot: g.u64(0..=1 << 8),
            r0: g.u64(0..=1 << 24),
            r1: g.u64(0..=1 << 24),
            total_rows: g.u64(0..=1 << 24),
            cols: g.u64(0..=1 << 24),
            chunk_rows: g.u64(0..=1 << 16),
            sketch_m: g.u64(0..=1 << 16),
            fd_rank: g.u64(0..=1 << 16),
            range_cap: g.u64(0..=1 << 16),
        },
        28 => Frame::PartitionRows { stream: g.u64(0..=u64::MAX), slot: g.u64(0..=1 << 8), rows: gmat(g) },
        29 => Frame::SealPartition { stream: g.u64(0..=u64::MAX), epoch: g.u64(0..=1 << 16) },
        30 => Frame::FreePartition { stream: g.u64(0..=u64::MAX) },
        // The telemetry scrape pair.
        31 => Frame::Metrics,
        32 => Frame::MetricsText { text: gstr(g) },
        _ => Frame::Unknown { tag: unassigned_tag(g) },
    }
}

// ---------------------------------------------------------------------
// Properties
// ---------------------------------------------------------------------

#[test]
fn every_frame_round_trips_bit_exactly() {
    check("wire round trip", 300, |g| {
        let req = g.u64(0..=u64::MAX);
        let frame = gframe(g);
        let bytes = encode_frame(req, &frame);
        let (got_req, got) = read_frame(&mut Cursor::new(&bytes))
            .map_err(|e| format!("decode of {frame:?} failed: {e}"))?;
        if got_req != req || got != frame {
            return Err(format!("round trip mutated: {frame:?} -> {got:?}"));
        }
        // Deterministic wire image: re-encoding the decoded frame must
        // reproduce the original bytes (no float/string normalisation).
        let again = encode_frame(got_req, &got);
        if again != bytes {
            return Err(format!("re-encode diverged for {frame:?}"));
        }
        Ok(())
    });
}

#[test]
fn every_truncated_prefix_is_a_typed_error() {
    check("truncation sweep", 120, |g| {
        let bytes = encode_frame(g.u64(0..=u64::MAX), &gframe(g));
        for cut in 0..bytes.len() {
            match read_frame(&mut Cursor::new(&bytes[..cut])) {
                Ok((_, frame)) => {
                    return Err(format!("prefix {cut}/{} decoded as {frame:?}", bytes.len()))
                }
                // Cut at the very start is a clean EOF; anywhere else a
                // typed truncation/decode error. Panics fail the test
                // harness on their own.
                Err(WireError::Closed) if cut == 0 => {}
                Err(_) => {}
            }
        }
        Ok(())
    });
}

#[test]
fn corrupt_bytes_never_panic_the_decoder() {
    check("corruption fuzz", 300, |g| {
        let mut bytes = encode_frame(g.u64(0..=u64::MAX), &gframe(g));
        let at = g.usize(0, bytes.len() - 1);
        let flip = g.u64(1..=255) as u8;
        bytes[at] ^= flip;
        // Any outcome but a panic is acceptable: a flipped byte may
        // still decode (e.g. inside string payload bytes) or surface
        // any typed WireError.
        let _ = read_frame(&mut Cursor::new(&bytes));
        Ok(())
    });
}

#[test]
fn unknown_tags_are_skipped_and_the_stream_continues() {
    check("unknown tag skip", 200, |g| {
        let tag = unassigned_tag(g);
        let req = g.u64(0..=u64::MAX);
        let junk = g.vec(0..=255, 0, 64);

        // Hand-craft the foreign frame: [len][req][tag][opaque payload].
        let mut stream = Vec::new();
        stream.extend_from_slice(&((8 + 2 + junk.len()) as u32).to_le_bytes());
        stream.extend_from_slice(&req.to_le_bytes());
        stream.extend_from_slice(&tag.to_le_bytes());
        stream.extend(junk.iter().map(|&b| b as u8));

        // A known frame rides right behind it on the same stream.
        let next = gframe(g);
        let next_req = g.u64(0..=u64::MAX);
        stream.extend_from_slice(&encode_frame(next_req, &next));

        let mut cur = Cursor::new(&stream);
        match read_frame(&mut cur) {
            Ok((r, Frame::Unknown { tag: t })) if r == req && t == tag => {}
            other => return Err(format!("foreign frame misread: {other:?}")),
        }
        // The opaque payload was fully consumed: the next frame decodes.
        match read_frame(&mut cur) {
            Ok((r, f)) if r == next_req && f == next => Ok(()),
            other => Err(format!("stream desynced after skip: {other:?}")),
        }
    });
}

#[test]
fn oversized_and_trailing_frames_are_refused() {
    check("oversized header", 100, |g| {
        // An announced length above the ceiling is refused before any
        // payload allocation.
        let len = g.u64(MAX_FRAME_BYTES as u64 + 1..=u32::MAX as u64) as u32;
        let mut bytes = len.to_le_bytes().to_vec();
        bytes.extend_from_slice(&[0u8; 16]);
        match read_frame(&mut Cursor::new(&bytes)) {
            Err(WireError::Oversized { len: got, max }) => {
                if got != len as usize || max != MAX_FRAME_BYTES {
                    return Err(format!("wrong oversize report: len {got}, max {max}"));
                }
            }
            other => return Err(format!("oversized frame not refused: {other:?}")),
        }

        // A well-formed body followed by covered-but-unconsumed bytes is
        // a typed Trailing error, not silent acceptance.
        let frame = gframe(g);
        if matches!(frame, Frame::Unknown { .. }) {
            return Ok(()); // Unknown consumes everything by design.
        }
        let full = encode_frame(g.u64(0..=u64::MAX), &frame);
        let extra = g.usize(1, 8);
        let mut body = full[4..].to_vec();
        body.extend(vec![0xEEu8; extra]);
        match decode_body(&body) {
            Err(WireError::Trailing { extra: got }) if got == extra => Ok(()),
            other => Err(format!("trailing bytes not refused for {frame:?}: {other:?}")),
        }
    });
}
