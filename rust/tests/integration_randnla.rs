//! Integration: optical-vs-digital statistical equivalence across all four
//! RandNLA algorithms — the machine-checkable form of Fig. 1.

use std::sync::Arc;

use photonic_randnla::graph::generators::erdos_renyi;
use photonic_randnla::graph::karate::{karate_club, KARATE_TRIANGLES};
use photonic_randnla::linalg::{self, rel_frobenius_error, Mat};
use photonic_randnla::opu::{NoiseModel, OpuConfig, OpuDevice};
use photonic_randnla::randnla::{
    approx_matmul_tn, estimate_triangles, exact_matmul_tn, hutchinson, nystrom, randsvd,
    DigitalSketcher, OpuSketcher, RandSvdOpts,
};
use photonic_randnla::reports::fig1;
use photonic_randnla::stats::Running;
use photonic_randnla::workload::{correlated_pair, psd_matrix};

fn opu(m: usize, n: usize, seed: u64) -> OpuSketcher {
    OpuSketcher::new(Arc::new(OpuDevice::new(OpuConfig::new(seed, m, n))))
}

#[test]
fn fig1_headline_optical_equals_numerical() {
    // The paper's central claim, across all four panels at small scale.
    let cfg = fig1::Fig1Config {
        n: 96,
        ratios: vec![0.25, 0.5, 1.0],
        trials: 3,
        seed: 11,
        noise: NoiseModel::realistic(),
    };
    let rows = fig1::all_panels(&cfg);
    fig1::optical_matches_numerical(&rows, 1.0)
        .expect("optical and numerical disagree beyond tolerance");
}

#[test]
fn matmul_optical_tracks_digital_across_compression() {
    let n = 128;
    let (a, b) = correlated_pair(n, 0.5, 1);
    let want = exact_matmul_tn(&a, &b);
    for (i, m) in [16usize, 64, 128].into_iter().enumerate() {
        let mut d = Running::new();
        let mut o = Running::new();
        for t in 0..3u64 {
            let seed = 100 + 31 * t + i as u64;
            d.push(rel_frobenius_error(&want, &approx_matmul_tn(&DigitalSketcher::new(m, n, seed), &a, &b)));
            o.push(rel_frobenius_error(&want, &approx_matmul_tn(&opu(m, n, seed), &a, &b)));
        }
        let gap = (o.mean() - d.mean()).abs() / d.mean();
        assert!(gap < 0.5, "m={m}: optical {:.3} vs digital {:.3}", o.mean(), d.mean());
    }
}

#[test]
fn trace_optical_unbiasedness() {
    let n = 96;
    let a = psd_matrix(n, n / 2, 2);
    let truth = a.trace();
    let mut est = Running::new();
    for t in 0..10u64 {
        est.push(hutchinson(&opu(48, n, 200 + t), &a));
    }
    let rel = (est.mean() - truth).abs() / truth;
    assert!(rel < 0.15, "optical Hutchinson biased: {rel}");
}

#[test]
fn karate_triangles_on_the_opu() {
    let g = karate_club();
    let mut est = Running::new();
    for t in 0..12u64 {
        est.push(estimate_triangles(&opu(30, 34, 300 + t), &g));
    }
    let rel = (est.mean() - KARATE_TRIANGLES as f64).abs() / KARATE_TRIANGLES as f64;
    assert!(rel < 0.8, "karate optical estimate off: mean {} ({rel})", est.mean());
}

#[test]
fn er_triangles_optical_vs_digital() {
    let g = erdos_renyi(128, 0.1, 3);
    let truth = g.exact_triangles() as f64;
    let (mut d, mut o) = (Running::new(), Running::new());
    for t in 0..6u64 {
        d.push(estimate_triangles(&DigitalSketcher::new(96, 128, 400 + t), &g));
        o.push(estimate_triangles(&opu(96, 128, 400 + t), &g));
    }
    let d_rel = (d.mean() - truth).abs() / truth;
    let o_rel = (o.mean() - truth).abs() / truth;
    assert!(d_rel < 0.5, "digital {d_rel}");
    assert!(o_rel < 0.6, "optical {o_rel}");
}

#[test]
fn randsvd_optical_matches_optimal_within_slack() {
    use photonic_randnla::workload::{matrix_with_spectrum, Spectrum};
    let n = 128;
    let a = matrix_with_spectrum(n, Spectrum::Exponential { decay: 0.85 }, 4);
    let k = 10;
    let best = rel_frobenius_error(&a, &linalg::truncated(&a, k));
    let r = randsvd(
        &opu(k + 8, n, 5),
        &a,
        RandSvdOpts { rank: k, oversample: 8, power_iters: 2, ..Default::default() },
    );
    let rec = linalg::reconstruct(&r.u, &r.s, &r.vt);
    let got = rel_frobenius_error(&a, &rec);
    assert!(got < 1.35 * best + 0.01, "optical randsvd {got} vs optimal {best}");
}

#[test]
fn nystrom_extension_works_optically() {
    // The core pseudo-inverse amplifies measurement noise, so judge the
    // median of several media rather than one unlucky draw (rcond also
    // set to shave noise-dominated core directions).
    let a = psd_matrix(96, 12, 6);
    let mut errs: Vec<f64> = (0..5u64)
        .map(|t| rel_frobenius_error(&a, &nystrom(&opu(48, 96, 7 + t), &a, 1e-3)))
        .collect();
    errs.sort_by(|x, y| x.partial_cmp(y).unwrap());
    let median = errs[2];
    assert!(median < 0.3, "optical Nystrom median error {median} ({errs:?})");
}

#[test]
fn noise_ablation_ideal_beats_harsh() {
    // C3: the claim "negligible precision loss" is about the *realistic*
    // operating point; the harsh point must measurably hurt — otherwise
    // our noise model is vacuous.
    let n = 96;
    let (a, b) = correlated_pair(n, 0.5, 8);
    let want = exact_matmul_tn(&a, &b);
    let err_with = |noise: NoiseModel| {
        let mut r = Running::new();
        for t in 0..4u64 {
            let dev = OpuDevice::new(OpuConfig::new(500 + t, 64, n).with_noise(noise.clone()));
            let s = OpuSketcher::new(Arc::new(dev));
            r.push(rel_frobenius_error(&want, &approx_matmul_tn(&s, &a, &b)));
        }
        r.mean()
    };
    let ideal = err_with(NoiseModel::ideal());
    let realistic = err_with(NoiseModel::realistic());
    let harsh = err_with(NoiseModel::harsh());
    // Realistic ~ ideal (the paper's claim), harsh strictly worse.
    assert!((realistic - ideal).abs() / ideal < 0.25, "realistic {realistic} vs ideal {ideal}");
    assert!(harsh > ideal, "harsh {harsh} should exceed ideal {ideal}");
}

#[test]
fn bit_depth_ablation_monotone() {
    // More DMD bit-planes => better linear projections.
    let n = 96;
    let mut rng = photonic_randnla::rng::Xoshiro256::new(9);
    let x = Mat::gaussian(n, 8, 1.0, &mut rng);
    let err_at = |bits: usize| {
        let dev = OpuDevice::new(OpuConfig::ideal(10, 48, n).with_bits(bits));
        let g = dev.effective_matrix();
        let want = linalg::matmul(&g, &x);
        let got = dev.project(&x);
        rel_frobenius_error(&want, &got)
    };
    let e2 = err_at(2);
    let e4 = err_at(4);
    let e8 = err_at(8);
    assert!(e4 < e2, "{e2} -> {e4}");
    assert!(e8 < e4, "{e4} -> {e8}");
}
