//! Seeded accuracy-regression tests for the adaptive-accuracy layer
//! (ISSUE 4 acceptance criteria):
//!
//! - **Hutch++ vs Hutchinson**: on the quality-figure test spectra,
//!   Hutch++ matches (or beats) plain Hutchinson's seeded relative
//!   trace error using **half** the total projection columns;
//! - **incremental rangefinder**: the a-posteriori gate is honest — the
//!   returned basis's *directly measured* error is <= the requested
//!   tolerance on synthetic low-rank + noise matrices;
//! - **adaptive randsvd**: `RandSvdOpts::tol` / `RandSvd { tol }` return
//!   a rank whose measured reconstruction error is <= tol;
//! - **bit-reproducibility**: both adaptive estimators through the full
//!   coordinator (pool + shard planner) are bit-identical across worker
//!   counts, like every other estimator;
//! - **sketch-and-precondition lstsq** through the coordinator lands on
//!   the exact least-squares solution.

use std::sync::atomic::Ordering;

use photonic_randnla::coordinator::{
    BatchConfig, Coordinator, CoordinatorConfig, JobSpec, LsqrOpts, OperandRef, Payload, Policy,
    PoolConfig, SubmitOptions, TraceEstimator,
};
use photonic_randnla::linalg::{self, rel_frobenius_error, Mat};
use photonic_randnla::opu::NoiseModel;
use photonic_randnla::randnla::backend::DigitalSketcher;
use photonic_randnla::randnla::{
    adaptive_range_digital, hutchinson, hutchpp_digital, randsvd, RandSvdOpts, RangeFinderOpts,
};
use photonic_randnla::rng::Xoshiro256;
use photonic_randnla::workload::{matrix_with_spectrum, psd_with_spectrum, Spectrum};

/// RMS relative trace error over seeds.
fn rms_rel<F: Fn(u64) -> f64>(truth: f64, trials: u64, est: F) -> f64 {
    let sq: f64 = (0..trials)
        .map(|t| {
            let e = (est(t) - truth) / truth;
            e * e
        })
        .sum();
    (sq / trials as f64).sqrt()
}

#[test]
fn hutchpp_matches_hutchinson_error_at_half_the_columns() {
    // The acceptance criterion, on both quality-figure spectra: Hutch++
    // at m/2 total projection columns must reach (at least) the seeded
    // accuracy Hutchinson gets from m columns.
    let spectra = [
        Spectrum::LowRankPlusNoise { rank: 8, noise: 1e-3 },
        Spectrum::Exponential { decay: 0.85 },
    ];
    let n = 64;
    let m = 64; // Hutchinson's budget; Hutch++ gets m/2
    let trials = 24u64;
    for (i, spec) in spectra.iter().enumerate() {
        let a = psd_with_spectrum(n, *spec, 100 + i as u64);
        let truth = a.trace();
        let hutch = rms_rel(truth, trials, |t| {
            hutchinson(&DigitalSketcher::new(m, n, 1_000 + 31 * t), &a)
        });
        let hpp = rms_rel(truth, trials, |t| hutchpp_digital(&a, m / 2, 2_000 + 37 * t));
        assert!(
            hpp <= hutch,
            "{spec:?}: hutch++ rms {hpp} at {} cols > hutchinson rms {hutch} at {m} cols",
            m / 2
        );
    }
}

#[test]
fn rangefinder_gate_is_honest_on_low_rank_plus_noise() {
    // For several ranks/tolerances the returned basis's *directly
    // measured* projection error must meet the tolerance.
    for (rank, tol, seed) in [(4usize, 0.1f64, 1u64), (8, 0.05, 2), (12, 0.02, 3)] {
        let a = matrix_with_spectrum(64, Spectrum::LowRankPlusNoise { rank, noise: 1e-3 }, seed);
        let r = adaptive_range_digital(
            &a,
            RangeFinderOpts { block: 4, max_rank: 48, tol },
            40 + seed,
        );
        assert!(r.converged, "rank {rank}: gate never passed ({})", r.rel_err);
        let proj = linalg::matmul(&r.q, &linalg::matmul_tn(&r.q, &a));
        let direct = rel_frobenius_error(&a, &proj);
        assert!(direct <= tol, "rank {rank}: measured {direct} > tol {tol}");
        assert!(
            r.q.cols < 2 * rank + 8,
            "rank {rank}: basis used {} columns (no adaptivity)",
            r.q.cols
        );
    }
}

#[test]
fn adaptive_randsvd_rank_meets_measured_tolerance() {
    let a = matrix_with_spectrum(64, Spectrum::Exponential { decay: 0.75 }, 5);
    let tol = 0.08;
    let s = DigitalSketcher::new(40, 64, 6);
    let r = randsvd(
        &s,
        &a,
        RandSvdOpts { rank: 32, oversample: 8, power_iters: 0, tol: Some(tol), block: 4 },
    );
    let rec = linalg::reconstruct(&r.u, &r.s, &r.vt);
    let rel = rel_frobenius_error(&a, &rec);
    assert!(rel <= tol, "measured {rel} > tol {tol}");
    assert!(r.s.len() < 32, "rank selection did not engage: {}", r.s.len());
    assert!(r.l < 40, "rangefinder never stopped early: {} columns", r.l);
}

fn host_coordinator(
    workers: usize,
    host_workers: usize,
    aperture: Option<(usize, usize)>,
) -> Coordinator {
    Coordinator::start(CoordinatorConfig {
        workers,
        policy: Policy::ForceHost,
        batch: BatchConfig {
            noise: NoiseModel::ideal(),
            max_wait: std::time::Duration::from_micros(50),
            ..Default::default()
        },
        pool: PoolConfig {
            pjrt_replicas: 0,
            host_workers,
            host_aperture: aperture,
            ..Default::default()
        },
        ..Default::default()
    })
    .unwrap()
}

#[test]
fn hutchpp_job_bit_reproducible_across_worker_counts_and_shards() {
    // The estimator contract every serving-plane estimator keeps: the
    // same job gives the bit-identical answer whatever the pool size —
    // here with an aperture small enough to force the shard planner on.
    let a = psd_with_spectrum(48, Spectrum::Exponential { decay: 0.8 }, 7);
    let run = |host_workers: usize| {
        let c = host_coordinator(2, host_workers, Some((8, 16)));
        let est = c
            .run_spec(
                JobSpec::Trace {
                    a: OperandRef::Inline(a.clone()),
                    m: 24,
                    estimator: TraceEstimator::HutchPP,
                },
                SubmitOptions::default(),
            )
            .unwrap()
            .payload
            .scalar()
            .unwrap();
        assert!(c.metrics.sharded_jobs.load(Ordering::Relaxed) >= 1, "shard planner idle");
        c.shutdown();
        est
    };
    let one = run(1);
    let three = run(3);
    assert_eq!(
        one.to_bits(),
        three.to_bits(),
        "hutch++ result depends on the pool size: {one} vs {three}"
    );
    // And it is accurate on this fast-decaying spectrum (single seeded
    // estimate — the band is generous; the seeded-RMS comparison above
    // is the sharp accuracy gate).
    let rel = (one - a.trace()).abs() / a.trace();
    assert!(rel < 0.1, "hutch++ through shards rel err {rel}");
}

#[test]
fn adaptive_randsvd_job_bit_reproducible_across_worker_counts() {
    let a = matrix_with_spectrum(48, Spectrum::LowRankPlusNoise { rank: 6, noise: 1e-3 }, 9);
    let tol = 0.05;
    let run = |host_workers: usize| {
        let c = host_coordinator(2, host_workers, Some((8, 16)));
        let resp = c
            .run_spec(
                JobSpec::RandSvd {
                    a: OperandRef::Inline(a.clone()),
                    rank: 16,
                    oversample: 8,
                    power_iters: 0,
                    publish_q: false,
                    tol: Some(tol),
                },
                SubmitOptions::default(),
            )
            .unwrap();
        assert!(c.metrics.adaptive_passes.load(Ordering::Relaxed) >= 1);
        c.shutdown();
        match resp.payload {
            Payload::Svd { u, s, vt } => (u, s, vt),
            _ => panic!("wrong payload"),
        }
    };
    let (u1, s1, vt1) = run(1);
    let (u3, s3, vt3) = run(3);
    assert_eq!(s1, s3, "singular values depend on the pool size");
    assert_eq!(u1, u3, "U depends on the pool size");
    assert_eq!(vt1, vt3, "V^T depends on the pool size");
    // The tolerance is honoured by the returned rank.
    let rec = linalg::reconstruct(&u1, &s1, &vt1);
    let rel = rel_frobenius_error(&a, &rec);
    assert!(rel <= tol, "adaptive randsvd via coordinator: {rel} > {tol}");
    assert!(s1.len() < 16, "rank selection did not engage: {}", s1.len());
}

#[test]
fn refined_lstsq_job_reaches_the_exact_argmin() {
    let c = host_coordinator(2, 1, None);
    let mut rng = Xoshiro256::new(13);
    let a = Mat::gaussian(256, 8, 1.0, &mut rng);
    let x_true: Vec<f64> = (0..8).map(|_| rng.next_normal()).collect();
    let mut b = linalg::matvec(&a, &x_true);
    for v in b.iter_mut() {
        *v += 0.4 * rng.next_normal();
    }
    let exact = photonic_randnla::randnla::exact_lstsq(&a, &b);
    let resp = c
        .run_spec(
            JobSpec::Lstsq {
                a: OperandRef::Inline(a),
                b,
                m: 64,
                refine: Some(LsqrOpts::default()),
            },
            SubmitOptions::default(),
        )
        .unwrap();
    let x = resp.payload.vector().unwrap();
    for (u, v) in x.iter().zip(&exact) {
        assert!((u - v).abs() < 1e-6, "{u} vs {v}");
    }
    c.shutdown();
}
