//! Acceptance tests for the streaming ingestion plane (ISSUE 5):
//!
//! - **Frequent Directions property**: across seeds and chunk sizes the
//!   directly measured `‖AᵀA − BᵀB‖₂` sits under the maintainer's
//!   measured bound Σδ, which in turn sits under the classic
//!   `‖A‖²_F/(ℓ−k)` guarantee;
//! - **one-pass vs resident**: a sealed stream's one-pass randSVD stays
//!   within the FD-derived tolerance of the resident-operand randSVD
//!   (whose range pass it reproduces *bit-identically* at
//!   `rank + oversample == range_cap`);
//! - **bit-reproducibility**: the full streaming pipeline — chunked
//!   ingest through the shard planner + one-pass consumers — is
//!   bit-identical across worker and replica counts for a fixed chunk
//!   schedule;
//! - **bounded memory**: the stream's quota bytes are a constant fixed
//!   at `begin`, released deterministically on seal/free/abort
//!   (`store_bytes` returns to baseline — the PR 3 aux-handle-reaping
//!   property extended to streams).

use std::sync::atomic::Ordering;

use photonic_randnla::coordinator::{
    BatchConfig, Coordinator, CoordinatorConfig, JobSpec, OperandRef, Policy, PoolConfig,
    StreamId, StreamOpts, SubmitOptions, TraceEstimator,
};
use photonic_randnla::linalg::{self, rel_frobenius_error, spectral_norm, Mat};
use photonic_randnla::opu::NoiseModel;
use photonic_randnla::randnla::FrequentDirections;
use photonic_randnla::rng::Xoshiro256;
use photonic_randnla::workload::{matrix_with_spectrum, psd_with_spectrum, Spectrum};

fn host_coordinator(
    workers: usize,
    host_workers: usize,
    aperture: Option<(usize, usize)>,
) -> Coordinator {
    Coordinator::start(CoordinatorConfig {
        workers,
        policy: Policy::ForceHost,
        batch: BatchConfig {
            noise: NoiseModel::ideal(),
            max_wait: std::time::Duration::from_micros(50),
            ..Default::default()
        },
        pool: PoolConfig {
            pjrt_replicas: 0,
            host_workers,
            host_aperture: aperture,
            ..Default::default()
        },
        ..Default::default()
    })
    .unwrap()
}

/// Chunk a matrix into a coordinator stream with the given chunk size.
fn ingest(c: &Coordinator, a: &Mat, opts: StreamOpts, chunk: usize) -> StreamId {
    let id = c.begin_stream(a.rows, a.cols, opts).unwrap();
    let mut r0 = 0usize;
    while r0 < a.rows {
        let r1 = (r0 + chunk).min(a.rows);
        let piece = Mat::from_fn(r1 - r0, a.cols, |i, j| a.at(r0 + i, j));
        c.append_stream(id, &piece).unwrap();
        r0 = r1;
    }
    c.seal_stream(id).unwrap();
    id
}

#[test]
fn fd_bound_holds_across_seeds_and_chunk_sizes() {
    // The satellite property test: measured spectral Gram error <=
    // measured Σδ <= ‖A‖²_F/(ℓ−k), across seeds and chunk schedules.
    let (n, ell, k) = (56usize, 14usize, 7usize);
    for seed in [2u64, 17, 41] {
        let a = matrix_with_spectrum(n, Spectrum::Exponential { decay: 0.8 }, seed);
        let fro2: f64 = a.data.iter().map(|v| v * v).sum();
        for chunk in [3usize, 11, 28, 56] {
            let mut fd = FrequentDirections::new(ell, n);
            let mut r0 = 0usize;
            while r0 < n {
                let r1 = (r0 + chunk).min(n);
                fd.insert(&Mat::from_fn(r1 - r0, n, |i, j| a.at(r0 + i, j)));
                r0 = r1;
            }
            fd.compress();
            let b = fd.sketch();
            let diff = linalg::matmul_tn(&a, &a).sub(&linalg::matmul_tn(&b, &b));
            let direct = spectral_norm(&diff, 300, 9);
            assert!(
                direct <= fd.bound() * (1.0 + 1e-9) + 1e-12 * fro2,
                "seed {seed} chunk {chunk}: measured {direct} above Σδ {}",
                fd.bound()
            );
            assert!(
                fd.bound() <= fro2 / (ell - k) as f64 + 1e-12 * fro2,
                "seed {seed} chunk {chunk}: Σδ {} above the ‖A‖²_F/(ℓ−k) guarantee",
                fd.bound()
            );
        }
    }
}

#[test]
fn one_pass_randsvd_matches_resident_within_the_fd_tolerance() {
    // ISSUE 5 acceptance: at rank + oversample == range_cap the stream
    // reproduces the resident range pass bit for bit, so the two
    // factorizations differ only by the one-pass co-range solve — which
    // the stream's FD certificate tolerances.
    let (n, rank, oversample) = (96usize, 8usize, 8usize);
    let cap = rank + oversample;
    let a = matrix_with_spectrum(n, Spectrum::LowRankPlusNoise { rank, noise: 1e-3 }, 5);
    let c = host_coordinator(2, 1, None);

    let resident = c
        .run_spec(
            JobSpec::RandSvd {
                a: OperandRef::Inline(a.clone()),
                rank,
                oversample,
                power_iters: 0,
                publish_q: false,
                tol: None,
            },
            SubmitOptions::default(),
        )
        .unwrap();
    let (ur, sr, vtr) = resident.payload.svd().unwrap();
    let rec_resident = linalg::reconstruct(ur, sr, vtr);

    let id = ingest(
        &c,
        &a,
        StreamOpts { chunk_rows: Some(32), sketch_m: 4 * cap, fd_rank: 2 * rank, range_cap: cap },
        32,
    );
    let fd_bound = c.streams().sealed(id).unwrap().fd_bound;
    let streamed = c
        .run_spec(
            JobSpec::RandSvd {
                a: OperandRef::Stream(id),
                rank,
                oversample,
                power_iters: 0,
                publish_q: false,
                tol: None,
            },
            SubmitOptions::default(),
        )
        .unwrap();
    let (us, ss, vts) = streamed.payload.svd().unwrap();
    let rec_streamed = linalg::reconstruct(us, ss, vts);

    // Tolerance derived from the run's *measured* certificates, not a
    // flat fudge factor, so a real co-range regression cannot hide:
    //
    // - FD term: Σδ bounds the Gram-space deviation, so
    //   sqrt(rank · Σδ)/‖A‖_F bounds the rank-k Frobenius drift the
    //   stream's summary error can induce;
    // - co-range term: X − QᵀA = (SQ)⁺·S·(A − QQᵀA), and with
    //   sketch_m = 4·cap the amplification ‖(SQ)⁺‖·‖S·‖ concentrates
    //   near sqrt(m_s)/(sqrt(m_s) − sqrt(cap)) = 2; the resident
    //   reconstruction error dominates ‖A − QQᵀA‖_F/‖A‖_F, so
    //   4 × resident_err gives the 2× amplification another 2× of
    //   concentration headroom (deterministic seeds — this is a fixed
    //   number, not a flaky band).
    let fro = {
        let fro2: f64 = a.data.iter().map(|v| v * v).sum();
        fro2.sqrt()
    };
    let resident_err = rel_frobenius_error(&a, &rec_resident);
    let tolerance = ((rank as f64) * fd_bound).sqrt() / fro + 4.0 * resident_err + 2e-3;
    let drift = rel_frobenius_error(&rec_resident, &rec_streamed);
    assert!(
        drift <= tolerance,
        "one-pass drifted {drift} from the resident factorization \
         (certificate tolerance {tolerance}, resident err {resident_err})"
    );
    // And both meet the usual quality bar against the target itself.
    assert!(rel_frobenius_error(&a, &rec_streamed) < 0.05);
    c.free_stream(id);
    c.shutdown();
}

#[test]
fn streaming_pipeline_is_bit_identical_across_pool_sizes() {
    // ISSUE 5 acceptance: one-pass streaming randSVD over a fixed chunk
    // schedule is bit-identical across worker and replica counts, with
    // the host aperture forcing the shard planner to split every chunk.
    let (n, rank, oversample, chunk) = (64usize, 6usize, 6usize, 16usize);
    let cap = rank + oversample;
    let a = matrix_with_spectrum(n, Spectrum::LowRankPlusNoise { rank, noise: 1e-3 }, 7);
    let run = |workers: usize, host_workers: usize| {
        let c = host_coordinator(workers, host_workers, Some((16, 16)));
        let id = ingest(
            &c,
            &a,
            StreamOpts {
                chunk_rows: Some(chunk),
                sketch_m: 4 * cap,
                fd_rank: 2 * rank,
                range_cap: cap,
            },
            chunk,
        );
        let resp = c
            .run_spec(
                JobSpec::RandSvd {
                    a: OperandRef::Stream(id),
                    rank,
                    oversample,
                    power_iters: 0,
                    publish_q: false,
                    tol: None,
                },
                SubmitOptions::default(),
            )
            .unwrap();
        let (u, s, vt) = resp.payload.svd().unwrap();
        let out = (u.clone(), s.to_vec(), vt.clone());
        assert!(c.metrics.sharded_jobs.load(Ordering::Relaxed) >= 1, "aperture never sharded");
        c.free_stream(id);
        c.shutdown();
        out
    };
    let one = run(1, 1);
    let four = run(3, 4);
    assert_eq!(one.1, four.1, "singular values depend on the pool size");
    assert_eq!(one.0, four.0, "U depends on the pool size");
    assert_eq!(one.2, four.2, "V^T depends on the pool size");
}

#[test]
fn streaming_trace_is_bit_identical_across_pool_sizes_and_near_truth() {
    let n = 64usize;
    let a = psd_with_spectrum(n, Spectrum::Exponential { decay: 0.8 }, 11);
    let run = |workers: usize, host_workers: usize| {
        let c = host_coordinator(workers, host_workers, Some((16, 16)));
        let id = ingest(
            &c,
            &a,
            StreamOpts { chunk_rows: Some(16), sketch_m: 48, fd_rank: 8, range_cap: 8 },
            16,
        );
        let est = c
            .run_spec(
                JobSpec::Trace {
                    a: OperandRef::Stream(id),
                    m: 48,
                    estimator: TraceEstimator::Hutchinson,
                },
                SubmitOptions::default(),
            )
            .unwrap()
            .payload
            .scalar()
            .unwrap();
        c.free_stream(id);
        c.shutdown();
        est
    };
    let one = run(1, 1);
    let four = run(3, 4);
    assert_eq!(one.to_bits(), four.to_bits(), "streaming trace depends on pool size");
    let truth = a.trace();
    assert!((one - truth).abs() / truth < 0.5, "trace estimate {one} vs {truth}");
}

#[test]
fn aborted_and_sealed_streams_release_their_quota_bytes() {
    // Satellite regression: store_bytes returns to baseline whatever the
    // stream's fate — abort mid-ingest, free-after-seal, or
    // free-while-a-job-holds-the-summaries.
    let c = host_coordinator(1, 1, None);
    let mut rng = Xoshiro256::new(3);
    let baseline = c.store().bytes();
    assert_eq!(baseline, 0);

    // Abort mid-ingest.
    let id = c
        .begin_stream(64, 32, StreamOpts { chunk_rows: Some(16), sketch_m: 8, fd_rank: 4, range_cap: 4 })
        .unwrap();
    c.append_stream(id, &Mat::gaussian(40, 32, 1.0, &mut rng)).unwrap();
    assert!(c.store().bytes() > baseline);
    assert!(c.free_stream(id));
    assert_eq!(c.store().bytes(), baseline, "aborted stream leaked quota bytes");
    assert_eq!(c.metrics.streams_aborted.load(Ordering::Relaxed), 1);

    // Seal, submit, free while the worker may still hold the Arc — the
    // job completes and the bytes are gone.
    let a = psd_with_spectrum(32, Spectrum::Exponential { decay: 0.7 }, 5);
    let id = ingest(
        &c,
        &a,
        StreamOpts { chunk_rows: Some(8), sketch_m: 16, fd_rank: 4, range_cap: 4 },
        8,
    );
    let t = c
        .submit_spec(
            JobSpec::Trace { a: OperandRef::Stream(id), m: 16, estimator: TraceEstimator::Hutchinson },
            SubmitOptions::default(),
        )
        .unwrap();
    assert!(c.free_stream(id));
    assert!(t.wait().is_ok(), "in-flight job stranded by free_stream");
    assert_eq!(c.store().bytes(), baseline, "sealed stream leaked quota bytes");
    assert_eq!(c.metrics.streams_aborted.load(Ordering::Relaxed), 1, "sealed free is not an abort");
    c.shutdown();
}

#[test]
fn streaming_lstsq_one_pass_solves_consistent_systems() {
    let c = host_coordinator(2, 1, None);
    let mut rng = Xoshiro256::new(19);
    let a = Mat::gaussian(160, 8, 1.0, &mut rng);
    let x_true: Vec<f64> = (0..8).map(|_| rng.next_normal()).collect();
    let b = linalg::matvec(&a, &x_true);
    let id = ingest(
        &c,
        &a,
        StreamOpts { chunk_rows: Some(32), sketch_m: 40, fd_rank: 8, range_cap: 8 },
        32,
    );
    let resp = c
        .run_spec(
            JobSpec::Lstsq { a: OperandRef::Stream(id), b, m: 40, refine: None },
            SubmitOptions::default(),
        )
        .unwrap();
    let x = resp.payload.vector().unwrap();
    for (u, v) in x.iter().zip(&x_true) {
        assert!((u - v).abs() < 1e-6, "{u} vs {v}");
    }
    c.free_stream(id);
    c.shutdown();
}
