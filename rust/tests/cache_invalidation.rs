//! Invalidation coverage for the result plane's content-addressed
//! sketch cache (ISSUE 7 satellite):
//!
//! - **free returns bytes**: freeing an operand synchronously evicts
//!   every cache entry derived from it and hands the parked bytes back
//!   to the store quota — no deferred/async reclamation to race with;
//! - **no stale service**: a fresh operand uploaded after a free never
//!   observes the freed operand's sketches (ids are never reused, so a
//!   stale hit would be a key-schema bug, not a data race);
//! - **stream invalidation**: `free_stream` drops the stream-derived
//!   entries (`StreamSym`, `StreamCorange`) the same way;
//! - **property-style interleavings**: a seeded random walk over
//!   upload / submit / bypass-submit / free keeps the cache within
//!   quota at every step, serves bit-identical results on hit and
//!   compute paths throughout, and drains to zero bytes when the last
//!   operand dies.

use std::collections::HashMap;
use std::sync::atomic::Ordering;

use photonic_randnla::coordinator::{
    BatchConfig, Coordinator, CoordinatorConfig, JobSpec, OperandRef, Policy, StreamOpts,
    SubmitOptions, TraceEstimator,
};
use photonic_randnla::linalg::Mat;
use photonic_randnla::opu::NoiseModel;
use photonic_randnla::rng::Xoshiro256;
use photonic_randnla::workload::psd_matrix;

fn cached_coordinator(workers: usize, cache_quota: usize) -> Coordinator {
    Coordinator::start(CoordinatorConfig {
        workers,
        policy: Policy::ForceHost,
        batch: BatchConfig {
            noise: NoiseModel::ideal(),
            max_wait: std::time::Duration::from_micros(50),
            ..Default::default()
        },
        cache_quota,
        ..Default::default()
    })
    .unwrap()
}

fn trace_spec(id: photonic_randnla::coordinator::OperandId, m: usize) -> JobSpec {
    JobSpec::Trace { a: OperandRef::Handle(id), m, estimator: TraceEstimator::Hutchinson }
}

#[test]
fn free_returns_parked_bytes_and_blocks_stale_hits() {
    let c = cached_coordinator(2, 1 << 20);
    let id = c.upload(psd_matrix(24, 48, 1)).unwrap();
    let store_baseline = c.store().bytes();

    c.run_spec(trace_spec(id, 12), SubmitOptions::default()).unwrap();
    let parked = c.cache().bytes();
    assert!(parked > 0, "miss must park the sketch");
    assert_eq!(
        c.store().bytes(),
        store_baseline + parked,
        "parked artifacts are store-quota-accounted"
    );

    assert!(c.free_operand(id));
    assert_eq!(c.cache().bytes(), 0, "invalidation is synchronous");
    assert_eq!(c.cache().len(), 0);
    assert_eq!(c.store().bytes(), 0, "operand + parked bytes all returned");

    // A different operand with identical dims gets a fresh id: the
    // submit below must MISS (and recompute), never resurrect the
    // freed operand's sketch.
    let id2 = c.upload(psd_matrix(24, 48, 2)).unwrap();
    c.run_spec(trace_spec(id2, 12), SubmitOptions::default()).unwrap();
    assert_eq!(c.metrics.cache_misses.load(Ordering::Relaxed), 2);
    assert_eq!(c.metrics.cache_hits.load(Ordering::Relaxed), 0, "stale hit served");
    c.shutdown();
}

#[test]
fn free_stream_drops_stream_derived_entries() {
    let c = cached_coordinator(2, 1 << 20);
    let sid = c
        .begin_stream(24, 24, StreamOpts { chunk_rows: None, sketch_m: 12, fd_rank: 4, range_cap: 8 })
        .unwrap();
    let mut rng = Xoshiro256::new(7);
    c.append_stream(sid, &Mat::gaussian(24, 24, 1.0, &mut rng)).unwrap();
    c.seal_stream(sid).unwrap();

    let spec = JobSpec::Trace {
        a: OperandRef::Stream(sid),
        m: 12,
        estimator: TraceEstimator::Hutchinson,
    };
    let cold = c.run_spec(spec.clone(), SubmitOptions::default()).unwrap();
    let hit = c.run_spec(spec, SubmitOptions::default()).unwrap();
    assert_eq!(
        cold.payload.scalar().unwrap().to_bits(),
        hit.payload.scalar().unwrap().to_bits()
    );
    assert_eq!(c.cache().len(), 1);

    assert!(c.free_stream(sid));
    assert_eq!(c.cache().len(), 0, "stream invalidation is synchronous");
    assert_eq!(c.cache().bytes(), 0);
    assert!(c.metrics.cache_evictions.load(Ordering::Relaxed) >= 1);
    c.shutdown();
}

/// Seeded random walk over the cache's whole external surface. The
/// quota is sized to hold only ~3 sketches so LRU eviction interleaves
/// with explicit invalidation; every submitted job is immediately
/// cross-checked against a `bypass_cache` run of the same spec, which
/// is the strongest "no stale service" oracle available: the compute
/// path re-projects from the live operand, so any cache entry surviving
/// past its operand (or aliased across operands) diverges bit-wise.
#[test]
fn random_interleavings_hold_quota_and_bit_identity_invariants() {
    let quota = 4 * 1024; // ~3 parked 12x12 f64 sketches
    for walk in 0..4u64 {
        let c = cached_coordinator(2, quota);
        let mut rng = Xoshiro256::new(0xCAFE + walk);
        let mut live: Vec<photonic_randnla::coordinator::OperandId> = Vec::new();
        let mut next_seed = 10 * (walk + 1);
        let mut first_bits: HashMap<(u64, usize), u64> = HashMap::new();

        for _step in 0..40 {
            match rng.next_u64() % 4 {
                // Upload a fresh operand (bounded population).
                0 if live.len() < 5 => {
                    next_seed += 1;
                    live.push(c.upload(psd_matrix(24, 48, next_seed)).unwrap());
                }
                // Free a random live operand: its entries must vanish.
                1 if !live.is_empty() => {
                    let idx = (rng.next_u64() as usize) % live.len();
                    let id = live.swap_remove(idx);
                    assert!(c.free_operand(id));
                }
                // Submit on a random live operand; cross-check bypass.
                _ if !live.is_empty() => {
                    let id = live[(rng.next_u64() as usize) % live.len()];
                    let m = if rng.next_u64() % 2 == 0 { 8 } else { 12 };
                    let served = c
                        .run_spec(trace_spec(id, m), SubmitOptions::default())
                        .unwrap()
                        .payload
                        .scalar()
                        .unwrap();
                    let computed = c
                        .run_spec(trace_spec(id, m), SubmitOptions::default().bypass_cache())
                        .unwrap()
                        .payload
                        .scalar()
                        .unwrap();
                    assert_eq!(
                        served.to_bits(),
                        computed.to_bits(),
                        "walk {walk}: cached path diverged from compute path"
                    );
                    // Deterministic operators: the value for (id, m) is
                    // fixed the first time we see it, hit or miss.
                    let prev = *first_bits.entry((id.0, m)).or_insert_with(|| served.to_bits());
                    assert_eq!(prev, served.to_bits(), "walk {walk}: value drifted");
                }
                _ => {}
            }
            assert!(
                c.cache().bytes() <= quota,
                "walk {walk}: cache {} bytes exceeds quota {quota}",
                c.cache().bytes()
            );
        }

        for id in live.drain(..) {
            assert!(c.free_operand(id));
        }
        assert_eq!(c.cache().bytes(), 0, "walk {walk}: bytes leaked past the last free");
        assert_eq!(c.cache().len(), 0);
        assert_eq!(c.store().bytes(), 0);
        c.shutdown();
    }
}
