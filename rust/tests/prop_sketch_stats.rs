//! Property tests for the structured sketch operators (testkit, our
//! proptest-lite): JL-style norm preservation in expectation over Philox
//! seeds, estimator accuracy through the Sketcher seam, and the shard
//! exactness contract for SRHT — mirroring tests/prop_sharding.rs:
//!
//! - output-dim sharding is **bit-identical** to the unsharded fast
//!   apply for 1–4 shards (each output row reads one sampled row of the
//!   same transform);
//! - input-dim sharding recombines to the unsharded projection up to
//!   f64 summation association (<= 1e-12 relative), bit-identically to
//!   the cell-sum reference folded in plan order.

use photonic_randnla::linalg::{matmul, rel_frobenius_error, Mat};
use photonic_randnla::parallel::split_ranges;
use photonic_randnla::randnla::backend::Sketcher;
use photonic_randnla::randnla::structured::{SparseSignSketcher, SrhtSketcher};
use photonic_randnla::randnla::{hutchinson, randsvd, RandSvdOpts};
use photonic_randnla::testkit::check;
use photonic_randnla::workload::{matrix_with_spectrum, psd_matrix, Spectrum};

#[test]
fn prop_srht_preserves_norms_in_expectation() {
    // JL over Philox seeds: E[||Sx||^2 / m] = ||x||^2, averaged over a
    // band of seeds for each random instance.
    check("SRHT JL norm preservation", 12, |g| {
        let n = g.usize(8, 160);
        let m = g.usize(8, 96);
        let mut rng = g.rng();
        let x = Mat::gaussian(n, 1, 1.0, &mut rng);
        let x2: f64 = x.data.iter().map(|v| v * v).sum();
        let trials = 64u64;
        let base = g.u64(0..=u64::MAX / 2);
        let mut acc = 0.0;
        for t in 0..trials {
            let s = SrhtSketcher::new(m, n, base + t);
            acc += s.project(&x).data.iter().map(|v| v * v).sum::<f64>() / m as f64;
        }
        let mean = acc / trials as f64;
        let rel = (mean - x2).abs() / x2;
        if rel > 0.25 {
            return Err(format!("JL violated at n={n} m={m}: {mean} vs {x2} ({rel})"));
        }
        Ok(())
    });
}

#[test]
fn prop_sparse_sign_preserves_norms_in_expectation() {
    check("sparse-sign JL norm preservation", 12, |g| {
        let n = g.usize(8, 160);
        let m = g.usize(8, 96);
        let s = g.usize(1, 8.min(m));
        let mut rng = g.rng();
        let x = Mat::gaussian(n, 1, 1.0, &mut rng);
        let x2: f64 = x.data.iter().map(|v| v * v).sum();
        let trials = 64u64;
        let base = g.u64(0..=u64::MAX / 2);
        let mut acc = 0.0;
        for t in 0..trials {
            let sk = SparseSignSketcher::new(m, n, s, base + t);
            acc += sk.project(&x).data.iter().map(|v| v * v).sum::<f64>() / m as f64;
        }
        let mean = acc / trials as f64;
        let rel = (mean - x2).abs() / x2;
        if rel > 0.3 {
            return Err(format!("JL violated at n={n} m={m} s={s}: {mean} vs {x2} ({rel})"));
        }
        Ok(())
    });
}

#[test]
fn prop_sharded_srht_bit_identical_1_to_4_output_shards() {
    check("1-4 SRHT output shards == unsharded fast apply, bitwise", 30, |g| {
        let m = g.usize(4, 40);
        let n = g.usize(4, 60);
        let k = g.usize(1, 6);
        let shards = g.usize(1, 4.min(m));
        let seed = g.u64(0..=u64::MAX);
        let s = SrhtSketcher::new(m, n, seed);
        let mut rng = g.rng();
        let x = Mat::gaussian(n, k, 1.0, &mut rng);
        let full = s.project(&x);
        let mut stacked = Mat::zeros(m, k);
        for r in split_ranges(m, shards) {
            let part = s.project_block(r.clone(), 0..n, &x);
            for (bi, i) in r.enumerate() {
                stacked.row_mut(i).copy_from_slice(part.row(bi));
            }
        }
        if stacked != full {
            return Err(format!(
                "output-dim SRHT sharding not bit-identical at m={m} n={n} shards={shards}"
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_sharded_srht_input_shards_recombine_exactly() {
    check("1-4 SRHT input shards: fold reference, ~unsharded", 30, |g| {
        let m = g.usize(4, 32);
        let n = g.usize(4, 64);
        let k = g.usize(1, 6);
        let shards = g.usize(1, 4.min(n));
        let seed = g.u64(0..=u64::MAX);
        let s = SrhtSketcher::new(m, n, seed);
        let mut rng = g.rng();
        let x = Mat::gaussian(n, k, 1.0, &mut rng);
        let full = s.project(&x);

        // Fold partials in plan order, twice: determinism must be bitwise.
        let fold = |sk: &SrhtSketcher| {
            let mut acc = Mat::zeros(m, k);
            for r in split_ranges(n, shards) {
                let xb = Mat::from_fn(r.len(), k, |i, j| x.at(r.start + i, j));
                acc = acc.add(&sk.project_block(0..m, r, &xb));
            }
            acc
        };
        let a = fold(&s);
        let b = fold(&SrhtSketcher::new(m, n, seed));
        if a != b {
            return Err(format!("SRHT shard fold nondeterministic at m={m} n={n}"));
        }
        let rel = rel_frobenius_error(&full, &a);
        if rel > 1e-12 {
            return Err(format!("input-dim SRHT drifted {rel} at m={m} n={n} shards={shards}"));
        }
        if shards == 1 && a != full {
            return Err("single input shard must be bit-identical".to_string());
        }
        Ok(())
    });
}

#[test]
fn prop_structured_blocks_match_explicit_operator() {
    // A materialised block times the matching input slice equals the
    // fast apply of that cell (the reroute-to-materialised escape hatch
    // and the fast path describe one operator).
    check("block matmul == fast apply per cell", 20, |g| {
        let m = g.usize(4, 24);
        let n = g.usize(4, 48);
        let k = g.usize(1, 4);
        let seed = g.u64(0..=u64::MAX);
        let mut rng = g.rng();
        let x = Mat::gaussian(n, k, 1.0, &mut rng);
        let srht = SrhtSketcher::new(m, n, seed);
        let sparse = SparseSignSketcher::new(m, n, g.usize(1, 4.min(m)), seed);

        let lo = g.usize(0, n - 1);
        let hi = g.usize(lo + 1, n);
        let xb = Mat::from_fn(hi - lo, k, |i, j| x.at(lo + i, j));
        let fast = srht.project_block(0..m, lo..hi, &xb);
        let explicit = matmul(&srht.block(0..m, lo..hi), &xb);
        let rel = rel_frobenius_error(&explicit, &fast);
        if rel > 1e-10 {
            return Err(format!("srht cell {lo}..{hi} drifted {rel}"));
        }
        let fast = sparse.project_block(0..m, lo..hi, &xb);
        let explicit = matmul(&sparse.block(0..m, lo..hi), &xb);
        let rel = rel_frobenius_error(&explicit, &fast);
        if rel > 1e-10 {
            return Err(format!("sparse cell {lo}..{hi} drifted {rel}"));
        }
        Ok(())
    });
}

#[test]
fn srht_hutchinson_unbiased_within_seed_tolerance() {
    // Same shape and tolerance as the dense trace test
    // (src/randnla/trace.rs::unbiased): mean over seeds within 3%.
    let a = psd_matrix(48, 96, 1);
    let truth = a.trace();
    let mut acc = 0.0;
    let trials = 400u64;
    for t in 0..trials {
        let s = SrhtSketcher::new(16, 48, 2000 + t);
        acc += hutchinson(&s, &a);
    }
    let mean = acc / trials as f64;
    let rel = (mean - truth).abs() / truth;
    assert!(rel < 0.03, "srht hutchinson bias {rel}");
}

#[test]
fn sparse_hutchinson_unbiased_within_seed_tolerance() {
    let a = psd_matrix(48, 96, 2);
    let truth = a.trace();
    let mut acc = 0.0;
    let trials = 400u64;
    for t in 0..trials {
        let s = SparseSignSketcher::new(16, 48, 4, 3000 + t);
        acc += hutchinson(&s, &a);
    }
    let mean = acc / trials as f64;
    let rel = (mean - truth).abs() / truth;
    assert!(rel < 0.05, "sparse hutchinson bias {rel}");
}

#[test]
fn srht_randsvd_recovers_low_rank_within_seed_tolerance() {
    // Same tolerance as the dense randsvd test
    // (src/randnla/randsvd.rs::recovers_low_rank_matrix).
    let n = 64;
    let a = matrix_with_spectrum(n, Spectrum::LowRankPlusNoise { rank: 8, noise: 1e-3 }, 1);
    let s = SrhtSketcher::new(24, n, 2);
    let opts = RandSvdOpts { rank: 8, oversample: 8, power_iters: 2, ..Default::default() };
    let r = randsvd(&s, &a, opts);
    let rec = photonic_randnla::randnla::randsvd::reconstruct(&r);
    let rel = rel_frobenius_error(&a, &rec);
    assert!(rel < 0.02, "srht randsvd recovery: {rel}");
}

#[test]
fn sparse_randsvd_recovers_low_rank_within_seed_tolerance() {
    let n = 64;
    let a = matrix_with_spectrum(n, Spectrum::LowRankPlusNoise { rank: 8, noise: 1e-3 }, 3);
    let s = SparseSignSketcher::new(24, n, 8, 4);
    let opts = RandSvdOpts { rank: 8, oversample: 8, power_iters: 2, ..Default::default() };
    let r = randsvd(&s, &a, opts);
    let rec = photonic_randnla::randnla::randsvd::reconstruct(&r);
    let rel = rel_frobenius_error(&a, &rec);
    assert!(rel < 0.02, "sparse randsvd recovery: {rel}");
}
