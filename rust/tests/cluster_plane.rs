//! Distributed-correctness suite for the scale-out plane.
//!
//! Loopback map workers join a coordinator's front door and the
//! coordinator partitions every stream across them; these tests pin the
//! plane's contract:
//!
//! - **accuracy**: 4-worker merged-summary one-pass RandSVD / Trace /
//!   Lstsq match the single-node one-pass path within the FD-derived
//!   tolerance (the summaries differ only by f64 association and the
//!   FD reduction tree, both covered by the composed certificate);
//! - **bit-identity**: the merged `S·A`, `Yᵀ`, and `‖A‖²_F` of a sealed
//!   cluster stream are bit-identical across 1-, 2-, and 4-worker
//!   partitions — the merge-slot grid and canonical ascending fold make
//!   the result independent of worker count;
//! - **failure**: a worker dying mid-ingest degrades to a typed
//!   `StreamError::Cluster` on the next stream call, never a hang;
//! - **memory**: `free_stream` on a cluster-partitioned stream releases
//!   the *worker-side* reserved bytes too — every node's
//!   `stream_resident_bytes` returns to baseline.

use std::sync::atomic::Ordering;
use std::time::{Duration, Instant};

use photonic_randnla::coordinator::{
    BatchConfig, Coordinator, CoordinatorConfig, JobSpec, OperandRef, Policy, PoolConfig,
    QosClass, StreamError, StreamId, StreamOpts, SubmitOptions, TenantRegistry, TraceEstimator,
};
use photonic_randnla::linalg::{self, matvec, rel_frobenius_error, Mat};
use photonic_randnla::net::{WireServer, WorkerConfig, WorkerNode};
use photonic_randnla::opu::NoiseModel;
use photonic_randnla::rng::Xoshiro256;
use photonic_randnla::testkit::ephemeral_loopback;
use photonic_randnla::workload::{matrix_with_spectrum, psd_with_spectrum, Spectrum};

fn coordinator() -> Coordinator {
    Coordinator::start(CoordinatorConfig {
        workers: 2,
        policy: Policy::ForceHost,
        batch: BatchConfig {
            noise: NoiseModel::ideal(),
            max_wait: Duration::from_micros(50),
            ..Default::default()
        },
        pool: PoolConfig { pjrt_replicas: 0, ..Default::default() },
        ..Default::default()
    })
    .expect("coordinator start")
}

/// Front door plus `n` loopback map workers, all deterministic-host.
fn cluster(n: usize) -> (WireServer, Vec<WorkerNode>) {
    let tenants = TenantRegistry::new().add("w", "wtok", usize::MAX, QosClass::Batch);
    let srv =
        WireServer::start(coordinator(), &ephemeral_loopback(), tenants).expect("server start");
    let workers: Vec<WorkerNode> = (0..n)
        .map(|i| {
            WorkerNode::connect(&srv.addr().to_string(), "wtok", WorkerConfig::default())
                .unwrap_or_else(|e| panic!("worker {i} join: {e}"))
        })
        .collect();
    let t0 = Instant::now();
    while srv.coordinator().cluster().worker_count() < n {
        assert!(t0.elapsed() < Duration::from_secs(10), "workers never registered");
        std::thread::sleep(Duration::from_millis(5));
    }
    (srv, workers)
}

/// Chunked ingest of `a` (the same driver the single-node suite uses;
/// on a cluster coordinator the rows route through the wire plane).
fn ingest(c: &Coordinator, a: &Mat, opts: StreamOpts, chunk: usize) -> StreamId {
    let id = c.begin_stream(a.rows, a.cols, opts).unwrap();
    let mut r0 = 0usize;
    while r0 < a.rows {
        let r1 = (r0 + chunk).min(a.rows);
        c.append_stream(id, &Mat::from_fn(r1 - r0, a.cols, |i, j| a.at(r0 + i, j))).unwrap();
        r0 = r1;
    }
    c.seal_stream(id).unwrap();
    id
}

#[test]
fn four_workers_match_single_node_within_fd_tolerance() {
    let (srv, workers) = cluster(4);
    let remote = srv.coordinator();
    let local = coordinator();

    // --- one-pass randSVD --------------------------------------------
    let (n, rank, oversample) = (96usize, 8usize, 8usize);
    let cap = rank + oversample;
    let a = matrix_with_spectrum(n, Spectrum::LowRankPlusNoise { rank, noise: 1e-3 }, 5);
    let opts = StreamOpts {
        chunk_rows: Some(16),
        sketch_m: 4 * cap,
        fd_rank: 2 * rank,
        range_cap: cap,
    };
    let svd_spec = |id: StreamId| JobSpec::RandSvd {
        a: OperandRef::Stream(id),
        rank,
        oversample,
        power_iters: 0,
        publish_q: false,
        tol: None,
    };
    let id_l = ingest(&local, &a, opts.clone(), 16);
    let id_r = ingest(remote, &a, opts, 16);
    let fdb_l = local.streams().sealed(id_l).unwrap().fd_bound;
    let fdb_r = remote.streams().sealed(id_r).unwrap().fd_bound;
    let (ul, sl, vtl) = {
        let r = local.run_spec(svd_spec(id_l), SubmitOptions::default()).unwrap();
        let (u, s, vt) = r.payload.svd().map(|(u, s, vt)| (u.clone(), s.to_vec(), vt.clone())).unwrap();
        (u, s, vt)
    };
    let (ur, sr, vtr) = {
        let r = remote.run_spec(svd_spec(id_r), SubmitOptions::default()).unwrap();
        let (u, s, vt) = r.payload.svd().map(|(u, s, vt)| (u.clone(), s.to_vec(), vt.clone())).unwrap();
        (u, s, vt)
    };
    let rec_l = linalg::reconstruct(&ul, &sl, &vtl);
    let rec_r = linalg::reconstruct(&ur, &sr, &vtr);
    // The two one-pass runs share every operator draw; they differ only
    // through the summaries, whose deviation the FD certificates bound.
    let fro = a.data.iter().map(|v| v * v).sum::<f64>().sqrt();
    let tolerance = ((rank as f64) * (fdb_l + fdb_r)).sqrt() / fro + 1e-9;
    let drift = rel_frobenius_error(&rec_l, &rec_r);
    assert!(
        drift <= tolerance,
        "cluster randsvd drifted {drift} from single-node (tolerance {tolerance})"
    );
    assert!(rel_frobenius_error(&a, &rec_r) < 0.05, "cluster factorization off target");
    assert!(local.free_stream(id_l));
    assert!(remote.free_stream(id_r));

    // --- one-pass trace ----------------------------------------------
    let p = psd_with_spectrum(64, Spectrum::Exponential { decay: 0.8 }, 11);
    let topts = StreamOpts { chunk_rows: Some(16), sketch_m: 32, fd_rank: 8, range_cap: 8 };
    let tr_spec = |id: StreamId| JobSpec::Trace {
        a: OperandRef::Stream(id),
        m: 32,
        estimator: TraceEstimator::Hutchinson,
    };
    let id_l = ingest(&local, &p, topts.clone(), 16);
    let id_r = ingest(remote, &p, topts, 16);
    let t_l = local.run_spec(tr_spec(id_l), SubmitOptions::default()).unwrap();
    let t_r = remote.run_spec(tr_spec(id_r), SubmitOptions::default()).unwrap();
    let (t_l, t_r) = (t_l.payload.scalar().unwrap(), t_r.payload.scalar().unwrap());
    assert!(
        (t_l - t_r).abs() <= 1e-9 * t_l.abs().max(1.0),
        "cluster trace {t_r} drifted from single-node {t_l}"
    );
    assert!(local.free_stream(id_l));
    assert!(remote.free_stream(id_r));

    // --- one-pass lstsq ----------------------------------------------
    let mut rng = Xoshiro256::new(19);
    let g = Mat::gaussian(160, 8, 1.0, &mut rng);
    let x_true: Vec<f64> = (0..8).map(|_| rng.next_normal()).collect();
    let b = matvec(&g, &x_true);
    let lopts = StreamOpts { chunk_rows: Some(32), sketch_m: 40, fd_rank: 8, range_cap: 8 };
    let ls_spec = |id: StreamId, b: Vec<f64>| JobSpec::Lstsq {
        a: OperandRef::Stream(id),
        b,
        m: 40,
        refine: None,
    };
    let id_l = ingest(&local, &g, lopts.clone(), 32);
    let id_r = ingest(remote, &g, lopts, 32);
    let x_l = local
        .run_spec(ls_spec(id_l, b.clone()), SubmitOptions::default())
        .unwrap()
        .payload
        .vector()
        .unwrap()
        .to_vec();
    let x_r = remote
        .run_spec(ls_spec(id_r, b), SubmitOptions::default())
        .unwrap()
        .payload
        .vector()
        .unwrap()
        .to_vec();
    for (l, r) in x_l.iter().zip(&x_r) {
        assert!((l - r).abs() < 1e-8, "cluster lstsq {r} drifted from single-node {l}");
    }
    for (r, t) in x_r.iter().zip(&x_true) {
        assert!((r - t).abs() < 1e-5, "cluster lstsq {r} off the true solution {t}");
    }
    assert!(local.free_stream(id_l));
    assert!(remote.free_stream(id_r));

    local.shutdown();
    drop(workers);
    srv.shutdown();
}

#[test]
fn merged_accumulators_are_bit_identical_across_worker_counts() {
    let mut rng = Xoshiro256::new(23);
    let a = Mat::gaussian(64, 12, 1.0, &mut rng);
    let opts = StreamOpts { chunk_rows: Some(8), sketch_m: 16, fd_rank: 8, range_cap: 4 };
    let summarize = |n_workers: usize| {
        let (srv, workers) = cluster(n_workers);
        let c = srv.coordinator();
        let id = ingest(c, &a, opts.clone(), 8);
        let sealed = c.streams().sealed(id).unwrap();
        let out = (sealed.sa.clone(), sealed.yt.clone(), sealed.fro2.to_bits());
        drop(sealed);
        assert!(c.free_stream(id));
        drop(workers);
        srv.shutdown();
        out
    };
    let one = summarize(1);
    let two = summarize(2);
    let four = summarize(4);
    assert_eq!(one.0, two.0, "S·A moved bits between 1 and 2 workers");
    assert_eq!(one.0, four.0, "S·A moved bits between 1 and 4 workers");
    assert_eq!(one.1, two.1, "Yᵀ moved bits between 1 and 2 workers");
    assert_eq!(one.1, four.1, "Yᵀ moved bits between 1 and 4 workers");
    assert_eq!(one.2, two.2, "fro2 moved bits between 1 and 2 workers");
    assert_eq!(one.2, four.2, "fro2 moved bits between 1 and 4 workers");
}

#[test]
fn worker_death_mid_ingest_degrades_typed_never_hangs() {
    let (srv, mut workers) = cluster(2);
    let c = srv.coordinator().clone();
    let a = {
        let mut rng = Xoshiro256::new(31);
        Mat::gaussian(64, 8, 1.0, &mut rng)
    };
    let opts = StreamOpts { chunk_rows: Some(8), sketch_m: 16, fd_rank: 8, range_cap: 4 };
    let id = c.begin_stream(64, 8, opts).unwrap();
    // Half the rows land before the failure.
    c.append_stream(id, &Mat::from_fn(32, 8, |i, j| a.at(i, j))).unwrap();

    // Kill one worker mid-ingest and wait for the coordinator to see
    // the disconnect (it poisons every stream holding that worker's
    // slots under the same lock that drops the registration).
    workers.remove(0).shutdown();
    let t0 = Instant::now();
    while c.cluster().worker_count() != 1 {
        assert!(t0.elapsed() < Duration::from_secs(10), "worker loss never observed");
        std::thread::sleep(Duration::from_millis(5));
    }

    // Every subsequent stream call fails typed, immediately.
    match c.append_stream(id, &Mat::from_fn(32, 8, |i, j| a.at(32 + i, j))) {
        Err(StreamError::Cluster(e)) => {
            let msg = e.to_string();
            assert!(!msg.is_empty());
        }
        other => panic!("append after worker death: expected Cluster error, got {other:?}"),
    }
    match c.seal_stream(id) {
        Err(StreamError::Cluster(_)) => {}
        other => panic!("seal after worker death: expected Cluster error, got {other:?}"),
    }
    // Submitting against the never-sealed stream is the usual typed
    // refusal, and free still reclaims everything.
    assert!(c.free_stream(id));
    drop(workers);
    srv.shutdown();
}

#[test]
fn free_stream_releases_worker_side_bytes_on_every_node() {
    let (srv, workers) = cluster(2);
    let c = srv.coordinator();
    let coord_baseline = c.metrics.stream_resident_bytes.load(Ordering::Relaxed);
    let store_baseline = c.store().bytes();
    let worker_baselines: Vec<u64> = workers
        .iter()
        .map(|w| w.metrics().stream_resident_bytes.load(Ordering::Relaxed))
        .collect();

    let a = {
        let mut rng = Xoshiro256::new(37);
        Mat::gaussian(64, 8, 1.0, &mut rng)
    };
    let opts = StreamOpts { chunk_rows: Some(8), sketch_m: 16, fd_rank: 8, range_cap: 4 };
    let id = c.begin_stream(64, 8, opts).unwrap();
    c.append_stream(id, &Mat::from_fn(24, 8, |i, j| a.at(i, j))).unwrap();

    // The partition assignments reserve bytes on the workers (async —
    // wait for at least one node to show them).
    let t0 = Instant::now();
    while workers
        .iter()
        .zip(&worker_baselines)
        .all(|(w, b)| w.metrics().stream_resident_bytes.load(Ordering::Relaxed) == *b)
    {
        assert!(t0.elapsed() < Duration::from_secs(10), "no worker ever reserved bytes");
        std::thread::sleep(Duration::from_millis(5));
    }

    // Free with the partition in flight: coordinator-side bytes release
    // synchronously, worker-side on the FreePartition round trip.
    assert!(c.free_stream(id));
    assert_eq!(
        c.metrics.stream_resident_bytes.load(Ordering::Relaxed),
        coord_baseline,
        "coordinator-side stream bytes leaked"
    );
    assert_eq!(c.store().bytes(), store_baseline, "store quota bytes leaked");
    let t0 = Instant::now();
    loop {
        let clean = workers.iter().zip(&worker_baselines).all(|(w, b)| {
            w.metrics().stream_resident_bytes.load(Ordering::Relaxed) == *b
        });
        if clean {
            break;
        }
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "worker-side stream bytes never returned to baseline"
        );
        std::thread::sleep(Duration::from_millis(5));
    }
    drop(workers);
    srv.shutdown();
}
