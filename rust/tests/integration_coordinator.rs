//! Integration: the full coordinator over real artifacts (L3 x runtime).
//!
//! PJRT-dependent cases self-skip when the artifact bundle (or the `xla`
//! feature) is absent: the coordinator now *degrades* to the OPU/host
//! arms instead of refusing to start, so asserting `Device::Pjrt` is only
//! meaningful when the engine actually comes up. Pool/shard cases at the
//! bottom run everywhere (no artifacts needed).

use std::path::PathBuf;
use std::time::Duration;

use photonic_randnla::coordinator::{
    BatchConfig, Coordinator, CoordinatorConfig, Device, Job, Payload, Policy, PoolConfig,
};
use photonic_randnla::linalg::{self, rel_frobenius_error, Mat};
use photonic_randnla::opu::NoiseModel;
use photonic_randnla::rng::Xoshiro256;
use photonic_randnla::runtime::PjrtEngine;
use photonic_randnla::workload::psd_matrix;

fn artifacts_dir() -> PathBuf {
    std::env::var("PHOTON_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// Whether a real PJRT engine can start (artifacts present + xla feature).
fn pjrt_available() -> bool {
    PjrtEngine::start(artifacts_dir()).is_ok()
}

fn coordinator(policy: Policy, workers: usize) -> Coordinator {
    Coordinator::start(CoordinatorConfig {
        workers,
        policy,
        batch: BatchConfig {
            max_wait: Duration::from_micros(100),
            noise: NoiseModel::ideal(),
            ..Default::default()
        },
        artifacts_dir: Some(artifacts_dir()),
        ..Default::default()
    })
    .expect("coordinator start")
}

#[test]
fn auto_routes_small_jobs_to_pjrt() {
    if !pjrt_available() {
        eprintln!("skipped: PJRT artifacts/runtime unavailable (run `make artifacts`)");
        return;
    }
    let c = coordinator(Policy::Auto, 2);
    let mut rng = Xoshiro256::new(1);
    let x = Mat::gaussian(128, 4, 1.0, &mut rng);
    let resp = c.run(Job::Projection { data: x, m: 32 }).unwrap();
    assert_eq!(resp.device, Device::Pjrt, "small jobs belong on the GPU arm");
    c.shutdown();
}

#[test]
fn force_opu_routes_to_opu_and_stays_accurate() {
    let c = coordinator(Policy::ForceOpu, 2);
    let mut rng = Xoshiro256::new(2);
    let x = Mat::gaussian(64, 4, 1.0, &mut rng);
    let resp = c.run(Job::Projection { data: x.clone(), m: 16 }).unwrap();
    assert_eq!(resp.device, Device::Opu);
    let p = resp.payload.matrix().unwrap();
    assert_eq!((p.rows, p.cols), (16, 4));
    // Norm preservation in expectation: |Gx| ~ sqrt(m)|x| within slop.
    let in_norm: f64 = x.data.iter().map(|v| v * v).sum::<f64>();
    let out_norm: f64 = p.data.iter().map(|v| v * v).sum::<f64>();
    let ratio = out_norm / (16.0 * in_norm);
    assert!(ratio > 0.2 && ratio < 5.0, "JL ratio {ratio}");
    c.shutdown();
}

#[test]
fn pjrt_and_host_agree_on_deterministic_sketch() {
    if !pjrt_available() {
        eprintln!("skipped: PJRT artifacts/runtime unavailable (run `make artifacts`)");
        return;
    }
    // Same (n, m) seed derivation => PJRT and Host arms use the same
    // counter-based G, so their results must agree to f32 precision.
    let mut rng = Xoshiro256::new(3);
    let x = Mat::gaussian(96, 3, 1.0, &mut rng);

    let c1 = coordinator(Policy::ForcePjrt, 1);
    let r1 = c1.run(Job::Projection { data: x.clone(), m: 24 }).unwrap();
    assert_eq!(r1.device, Device::Pjrt);
    c1.shutdown();

    let c2 = coordinator(Policy::ForceHost, 1);
    let r2 = c2.run(Job::Projection { data: x, m: 24 }).unwrap();
    assert_eq!(r2.device, Device::Host);
    c2.shutdown();

    let rel = rel_frobenius_error(r2.payload.matrix().unwrap(), r1.payload.matrix().unwrap());
    assert!(rel < 1e-5, "pjrt vs host sketch mismatch: {rel}");
}

#[test]
fn trace_job_via_pjrt_is_accurate() {
    // Runs on the PJRT arm when available, host fallback otherwise — the
    // estimator accuracy contract is arm-independent.
    let c = coordinator(Policy::ForcePjrt, 2);
    let a = psd_matrix(128, 64, 4);
    let truth = a.trace();
    let est = c
        .run(Job::Trace { a, m: 96 })
        .unwrap()
        .payload
        .scalar()
        .unwrap();
    let rel = (est - truth).abs() / truth;
    assert!(rel < 0.4, "trace est {est} vs {truth} ({rel})");
    c.shutdown();
}

#[test]
fn randsvd_job_via_pjrt_recovers_low_rank() {
    use photonic_randnla::workload::{matrix_with_spectrum, Spectrum};
    let c = coordinator(Policy::ForcePjrt, 2);
    let a = matrix_with_spectrum(96, Spectrum::LowRankPlusNoise { rank: 6, noise: 1e-3 }, 5);
    let resp = c
        .run(Job::RandSvd { a: a.clone(), rank: 6, oversample: 6, power_iters: 2 })
        .unwrap();
    match resp.payload {
        Payload::Svd { u, s, vt } => {
            let rec = linalg::reconstruct(&u, &s, &vt);
            assert!(rel_frobenius_error(&a, &rec) < 0.02);
        }
        _ => panic!("expected SVD payload"),
    }
    c.shutdown();
}

#[test]
fn throughput_batching_kicks_in_under_load() {
    let c = coordinator(Policy::ForcePjrt, 4);
    let mut rng = Xoshiro256::new(6);
    let tickets: Vec<_> = (0..32)
        .map(|_| {
            let x = Mat::gaussian(64, 2, 1.0, &mut rng);
            c.submit(Job::Projection { data: x, m: 16 })
        })
        .collect();
    for t in tickets {
        t.wait().unwrap();
    }
    assert_eq!(c.metrics.completed.load(std::sync::atomic::Ordering::Relaxed), 32);
    // Under concurrent submission at one signature, batching must merge.
    assert!(
        c.metrics.mean_batch_cols() > 2.0,
        "no batching observed: {}",
        c.metrics.mean_batch_cols()
    );
    c.shutdown();
}

#[test]
fn mixed_workload_completes_and_reports() {
    let c = coordinator(Policy::Auto, 4);
    let mut rng = Xoshiro256::new(7);
    let mut tickets = Vec::new();
    for i in 0..12u64 {
        let job = match i % 4 {
            0 => Job::Projection { data: Mat::gaussian(64, 2, 1.0, &mut rng), m: 16 },
            1 => Job::Trace { a: psd_matrix(64, 32, i), m: 32 },
            2 => {
                let g = photonic_randnla::graph::generators::erdos_renyi(64, 0.1, i);
                Job::Triangles { adjacency: g.adjacency(), m: 48 }
            }
            _ => Job::ApproxMatmul {
                a: Mat::gaussian(64, 4, 1.0, &mut rng),
                b: Mat::gaussian(64, 4, 1.0, &mut rng),
                m: 32,
            },
        };
        tickets.push(c.submit(job));
    }
    for t in tickets {
        let r = t.wait().unwrap();
        assert!(r.latency_us > 0);
    }
    let report = c.metrics.report();
    assert!(report.contains("completed=12"), "{report}");
    c.shutdown();
}

// ---- pool / shard integration (no artifacts required) ----

#[test]
fn oversized_jobs_complete_on_pooled_coordinator_under_mixed_load() {
    // A pool of small-aperture OPU replicas serving a mix of fitting and
    // oversized projections concurrently: everything completes, oversized
    // batches go through the shard planner.
    let c = Coordinator::start(CoordinatorConfig {
        workers: 4,
        policy: Policy::ForceOpu,
        batch: BatchConfig {
            max_wait: Duration::from_micros(100),
            max_cols: 8,
            noise: NoiseModel::ideal(),
            ..Default::default()
        },
        pool: PoolConfig {
            opu_replicas: 3,
            pjrt_replicas: 0,
            opu_aperture: Some((24, 48)),
            ..Default::default()
        },
        artifacts_dir: None,
        ..Default::default()
    })
    .expect("pooled coordinator start");
    let mut rng = Xoshiro256::new(8);
    let mut tickets = Vec::new();
    for i in 0..9 {
        let n = if i % 3 == 0 { 96 } else { 32 }; // 96 > 48: input-sharded
        let m = if i % 3 == 1 { 48 } else { 16 }; // 48 > 24: output-sharded
        let x = Mat::gaussian(n, 2, 1.0, &mut rng);
        tickets.push((m, n, c.submit(Job::Projection { data: x, m })));
    }
    for (m, _n, t) in tickets {
        let r = t.wait().unwrap();
        let p = r.payload.matrix().unwrap();
        assert_eq!(p.rows, m);
        assert_eq!(r.device, Device::Opu);
    }
    let sharded = c.metrics.sharded_jobs.load(std::sync::atomic::Ordering::Relaxed);
    assert!(sharded >= 1, "no batch went through the shard planner");
    assert_eq!(c.metrics.failed.load(std::sync::atomic::Ordering::Relaxed), 0);
    c.shutdown();
}

#[test]
fn pool_survives_replica_loss_under_concurrent_load() {
    let c = Coordinator::start(CoordinatorConfig {
        workers: 4,
        policy: Policy::ForceOpu,
        batch: BatchConfig {
            max_wait: Duration::from_micros(50),
            max_cols: 2,
            noise: NoiseModel::ideal(),
            ..Default::default()
        },
        pool: PoolConfig { opu_replicas: 2, pjrt_replicas: 0, ..Default::default() },
        artifacts_dir: None,
        ..Default::default()
    })
    .expect("pooled coordinator start");
    let mut rng = Xoshiro256::new(9);
    // First wave primes both replicas.
    for _ in 0..4 {
        let x = Mat::gaussian(40, 2, 1.0, &mut rng);
        c.run(Job::Projection { data: x, m: 12 }).unwrap();
    }
    // Kill one replica mid-run, then push a concurrent wave.
    assert!(c.kill_replica(Device::Opu, 0));
    let tickets: Vec<_> = (0..6)
        .map(|_| {
            let x = Mat::gaussian(40, 2, 1.0, &mut rng);
            c.submit(Job::Projection { data: x, m: 12 })
        })
        .collect();
    for t in tickets {
        t.wait().unwrap();
    }
    assert_eq!(c.metrics.failed.load(std::sync::atomic::Ordering::Relaxed), 0);
    assert_eq!(c.metrics.completed.load(std::sync::atomic::Ordering::Relaxed), 10);
    c.shutdown();
}
