//! Integration: the full coordinator over real artifacts (L3 x runtime).

use std::path::PathBuf;
use std::time::Duration;

use photonic_randnla::coordinator::{
    BatchConfig, Coordinator, CoordinatorConfig, Device, Job, Payload, Policy,
};
use photonic_randnla::linalg::{self, rel_frobenius_error, Mat};
use photonic_randnla::opu::NoiseModel;
use photonic_randnla::rng::Xoshiro256;
use photonic_randnla::workload::psd_matrix;

fn artifacts_dir() -> PathBuf {
    std::env::var("PHOTON_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

fn coordinator(policy: Policy, workers: usize) -> Coordinator {
    Coordinator::start(CoordinatorConfig {
        workers,
        policy,
        batch: BatchConfig {
            max_wait: Duration::from_micros(100),
            noise: NoiseModel::ideal(),
            ..Default::default()
        },
        artifacts_dir: Some(artifacts_dir()),
    })
    .expect("coordinator start (run `make artifacts`)")
}

#[test]
fn auto_routes_small_jobs_to_pjrt() {
    let c = coordinator(Policy::Auto, 2);
    let mut rng = Xoshiro256::new(1);
    let x = Mat::gaussian(128, 4, 1.0, &mut rng);
    let resp = c.run(Job::Projection { data: x, m: 32 }).unwrap();
    assert_eq!(resp.device, Device::Pjrt, "small jobs belong on the GPU arm");
    c.shutdown();
}

#[test]
fn force_opu_routes_to_opu_and_stays_accurate() {
    let c = coordinator(Policy::ForceOpu, 2);
    let mut rng = Xoshiro256::new(2);
    let x = Mat::gaussian(64, 4, 1.0, &mut rng);
    let resp = c.run(Job::Projection { data: x.clone(), m: 16 }).unwrap();
    assert_eq!(resp.device, Device::Opu);
    let p = resp.payload.matrix().unwrap();
    assert_eq!((p.rows, p.cols), (16, 4));
    // Norm preservation in expectation: |Gx| ~ sqrt(m)|x| within slop.
    let in_norm: f64 = x.data.iter().map(|v| v * v).sum::<f64>();
    let out_norm: f64 = p.data.iter().map(|v| v * v).sum::<f64>();
    let ratio = out_norm / (16.0 * in_norm);
    assert!(ratio > 0.2 && ratio < 5.0, "JL ratio {ratio}");
    c.shutdown();
}

#[test]
fn pjrt_and_host_agree_on_deterministic_sketch() {
    // Same (n, m) seed derivation => PJRT and Host arms use the same G,
    // so their results must agree to f32 precision.
    let mut rng = Xoshiro256::new(3);
    let x = Mat::gaussian(96, 3, 1.0, &mut rng);

    let c1 = coordinator(Policy::ForcePjrt, 1);
    let r1 = c1.run(Job::Projection { data: x.clone(), m: 24 }).unwrap();
    assert_eq!(r1.device, Device::Pjrt);
    c1.shutdown();

    let c2 = coordinator(Policy::ForceHost, 1);
    let r2 = c2.run(Job::Projection { data: x, m: 24 }).unwrap();
    assert_eq!(r2.device, Device::Host);
    c2.shutdown();

    let rel = rel_frobenius_error(r2.payload.matrix().unwrap(), r1.payload.matrix().unwrap());
    assert!(rel < 1e-5, "pjrt vs host sketch mismatch: {rel}");
}

#[test]
fn trace_job_via_pjrt_is_accurate() {
    let c = coordinator(Policy::ForcePjrt, 2);
    let a = psd_matrix(128, 64, 4);
    let truth = a.trace();
    let est = c
        .run(Job::Trace { a, m: 96 })
        .unwrap()
        .payload
        .scalar()
        .unwrap();
    let rel = (est - truth).abs() / truth;
    assert!(rel < 0.4, "trace est {est} vs {truth} ({rel})");
    c.shutdown();
}

#[test]
fn randsvd_job_via_pjrt_recovers_low_rank() {
    use photonic_randnla::workload::{matrix_with_spectrum, Spectrum};
    let c = coordinator(Policy::ForcePjrt, 2);
    let a = matrix_with_spectrum(96, Spectrum::LowRankPlusNoise { rank: 6, noise: 1e-3 }, 5);
    let resp = c
        .run(Job::RandSvd { a: a.clone(), rank: 6, oversample: 6, power_iters: 2 })
        .unwrap();
    match resp.payload {
        Payload::Svd { u, s, vt } => {
            let rec = linalg::reconstruct(&u, &s, &vt);
            assert!(rel_frobenius_error(&a, &rec) < 0.02);
        }
        _ => panic!("expected SVD payload"),
    }
    c.shutdown();
}

#[test]
fn throughput_batching_kicks_in_under_load() {
    let c = coordinator(Policy::ForcePjrt, 4);
    let mut rng = Xoshiro256::new(6);
    let tickets: Vec<_> = (0..32)
        .map(|_| {
            let x = Mat::gaussian(64, 2, 1.0, &mut rng);
            c.submit(Job::Projection { data: x, m: 16 })
        })
        .collect();
    for t in tickets {
        t.wait().unwrap();
    }
    assert_eq!(c.metrics.completed.load(std::sync::atomic::Ordering::Relaxed), 32);
    // Under concurrent submission at one signature, batching must merge.
    assert!(
        c.metrics.mean_batch_cols() > 2.0,
        "no batching observed: {}",
        c.metrics.mean_batch_cols()
    );
    c.shutdown();
}

#[test]
fn mixed_workload_completes_and_reports() {
    let c = coordinator(Policy::Auto, 4);
    let mut rng = Xoshiro256::new(7);
    let mut tickets = Vec::new();
    for i in 0..12u64 {
        let job = match i % 4 {
            0 => Job::Projection { data: Mat::gaussian(64, 2, 1.0, &mut rng), m: 16 },
            1 => Job::Trace { a: psd_matrix(64, 32, i), m: 32 },
            2 => {
                let g = photonic_randnla::graph::generators::erdos_renyi(64, 0.1, i);
                Job::Triangles { adjacency: g.adjacency(), m: 48 }
            }
            _ => Job::ApproxMatmul {
                a: Mat::gaussian(64, 4, 1.0, &mut rng),
                b: Mat::gaussian(64, 4, 1.0, &mut rng),
                m: 32,
            },
        };
        tickets.push(c.submit(job));
    }
    for t in tickets {
        let r = t.wait().unwrap();
        assert!(r.latency_us > 0);
    }
    let report = c.metrics.report();
    assert!(report.contains("completed=12"), "{report}");
    c.shutdown();
}
